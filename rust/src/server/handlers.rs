//! Endpoint handlers: route a parsed [`Request`] against a [`Registry`]
//! snapshot, producing JSON metadata, raw ROI bytes, ingest/delete/
//! rescan outcomes, or the uniform error body. Pure functions over
//! `(&registry, &request)` — no sockets — so the whole status-code
//! matrix is unit-testable without binding a port, and the connection
//! loop stays a thin shell.
//!
//! Read handlers take one [`Registry::snapshot`] per request and never
//! observe a concurrent swap. Write handlers (`PUT`, `DELETE`, rescan)
//! go through the registry's serialized mutation path; a read-only
//! registry answers **503** to all of them.
//!
//! Status-code contract (specified in `docs/SERVE.md`): unknown
//! artifact/field/chunk → **404**; syntactically valid but out-of-bounds
//! or empty row ranges → **416** with a `Content-Range: rows */total`
//! header; malformed parameters or ingest framing → **400**; wrong
//! method on a known route → **405** with an accurate `Allow`; ingest
//! slots busy → **429** with `Retry-After`; reader-level failures (e.g.
//! a chunk failing CRC under an active request) → **500**.

use super::http::{json_escape, Request, Response};
use super::stats::ServerStats;
use super::{ArtifactStore, Registry};
use crate::config::{JobConfig, Json};
use crate::data::{Field, FieldValues};
use crate::error::SzError;
use crate::obs;
use crate::util::parse_rows;
use std::time::Instant;

/// Route `req`, answer it, and record its latency under the endpoint
/// label — the single entry point the connection loop calls. Latency is
/// double-entried: into the per-server [`ServerStats`] (for `/statsz`)
/// and into the process-wide [`obs`] registry (for `/metricsz`).
pub fn dispatch(registry: &Registry, stats: &ServerStats, req: &Request) -> Response {
    dispatch_labeled(registry, stats, req).1
}

/// [`dispatch`], but also return the endpoint label so the connection
/// loop can stamp access-log lines without re-routing.
pub fn dispatch_labeled(
    registry: &Registry,
    stats: &ServerStats,
    req: &Request,
) -> (&'static str, Response) {
    let t0 = Instant::now();
    let (label, resp) = route(registry, stats, req);
    let elapsed = t0.elapsed();
    stats.record(label, elapsed);
    obs::http_record(obs::http_slot(label), elapsed, resp.body.len() as u64);
    (label, resp)
}

/// Match `(method, path)` to a handler; returns the endpoint label used
/// for latency accounting alongside the response. Wrong methods on
/// known routes get a 405 whose `Allow` header lists exactly what that
/// route accepts.
pub fn route(
    registry: &Registry,
    stats: &ServerStats,
    req: &Request,
) -> (&'static str, Response) {
    // one coherent snapshot per request: concurrent publishes/removes
    // swap the registry pointer without disturbing this store
    let snap = registry.snapshot();
    let read = matches!(req.method.as_str(), "GET" | "HEAD");
    let segs = req.segments();
    let segs: Vec<&str> = segs.iter().map(String::as_str).collect();
    match segs.as_slice() {
        ["healthz"] if read => ("healthz", healthz(registry, &snap, stats)),
        ["statsz"] if read => ("statsz", statsz(&snap, stats)),
        ["metricsz"] if read => ("metricsz", metricsz()),
        ["v1", "artifacts"] if read => ("list", list(&snap)),
        ["v1", "artifacts", id] if read => ("meta", meta(&snap, id)),
        ["v1", "artifacts", id] if req.method == "PUT" => {
            ("ingest", ingest(registry, req, id))
        }
        ["v1", "artifacts", id] if req.method == "DELETE" => {
            ("delete", delete_artifact(registry, id))
        }
        ["v1", "artifacts", id, "fields", name] if read => {
            ("roi", roi(&snap, req, id, name))
        }
        ["v1", "artifacts", id, "raw"] if read => ("raw", raw(&snap, req, id)),
        ["v1", "admin", "rescan"] if req.method == "POST" => {
            ("rescan", rescan(registry))
        }
        // known routes, wrong method: accurate Allow per route
        ["v1", "artifacts", _] => {
            ("other", method_not_allowed(&req.method, "GET, HEAD, PUT, DELETE"))
        }
        ["v1", "admin", "rescan"] => {
            ("other", method_not_allowed(&req.method, "POST"))
        }
        ["healthz"] | ["statsz"] | ["metricsz"] | ["v1", "artifacts"]
        | ["v1", "artifacts", _, "fields", _] | ["v1", "artifacts", _, "raw"] => {
            ("other", method_not_allowed(&req.method, "GET, HEAD"))
        }
        _ => ("other", Response::error(404, &format!("no route for {}", req.path))),
    }
}

fn method_not_allowed(method: &str, allow: &'static str) -> Response {
    Response::error(405, &format!("method {method} not allowed"))
        .with_header("Allow", allow)
}

fn healthz(
    registry: &Registry,
    store: &ArtifactStore,
    stats: &ServerStats,
) -> Response {
    Response::json(
        200,
        format!(
            "{{\"status\":\"ok\",\"artifacts\":{},\"generation\":{},\
             \"writable\":{},\"uptime_s\":{:.1}}}",
            store.artifacts().len(),
            registry.generation(),
            registry.writable(),
            stats.uptime_s()
        ),
    )
}

fn list(store: &ArtifactStore) -> Response {
    let items: Vec<String> = store
        .artifacts()
        .iter()
        .map(|a| {
            let names: Vec<String> = a
                .fields
                .iter()
                .map(|f| format!("\"{}\"", json_escape(&f.name)))
                .collect();
            format!(
                "{{\"id\":\"{}\",\"version\":{},\"file_bytes\":{},\"payload_bytes\":{},\
                 \"fields\":[{}],\"chunks\":{},\"snapshots\":{}}}",
                json_escape(&a.id),
                a.reader.version(),
                a.file_bytes,
                a.reader.payload_bytes(),
                names.join(","),
                a.reader.index().entries.len(),
                a.reader.snapshot_count()
            )
        })
        .collect();
    Response::json(200, format!("{{\"artifacts\":[{}]}}", items.join(",")))
}

fn meta(store: &ArtifactStore, id: &str) -> Response {
    let art = match store.get(id) {
        Some(a) => a,
        None => return Response::error(404, &format!("unknown artifact '{id}'")),
    };
    let snapshots: Vec<String> = art
        .reader
        .snapshot_tags()
        .iter()
        .enumerate()
        .map(|(id, tag)| {
            format!("{{\"id\":{id},\"tag\":\"{}\"}}", json_escape(tag))
        })
        .collect();
    let mut fields = Vec::new();
    for f in &art.fields {
        // chunk map across all snapshots, ordered (snapshot, chunk_index);
        // `entry` is the global index ordinal a client passes to
        // `/raw?chunk=N`
        let mut entries: Vec<(usize, &crate::container::ChunkEntry)> = art
            .reader
            .index()
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.field == f.name)
            .collect();
        entries.sort_by_key(|(_, e)| (e.snapshot, e.chunk_index));
        let map: Vec<String> = entries
            .iter()
            .map(|(entry_id, e)| {
                format!(
                    "{{\"chunk\":{},\"entry\":{},\"snapshot\":{},\"delta\":{},\
                     \"rows\":[{},{}],\"pipeline\":\"{}\",\
                     \"bytes\":{},\"crc32\":{}}}",
                    e.chunk_index,
                    entry_id,
                    e.snapshot,
                    e.delta,
                    e.rows.0,
                    e.rows.1,
                    json_escape(&e.pipeline),
                    e.len,
                    match e.crc32 {
                        Some(c) => c.to_string(),
                        None => "null".to_string(),
                    }
                )
            })
            .collect();
        fields.push(format!(
            "{{\"name\":\"{}\",\"dtype\":\"{}\",\"dims\":{},\"chunks\":{},\
             \"chunk_map\":[{}]}}",
            json_escape(&f.name),
            json_escape(&f.dtype),
            dims_json(&f.dims),
            f.chunks,
            map.join(",")
        ));
    }
    Response::json(
        200,
        format!(
            "{{\"id\":\"{}\",\"version\":{},\"file_bytes\":{},\"payload_bytes\":{},\
             \"snapshots\":[{}],\"fields\":[{}]}}",
            json_escape(&art.id),
            art.reader.version(),
            art.file_bytes,
            art.reader.payload_bytes(),
            snapshots.join(","),
            fields.join(",")
        ),
    )
}

fn roi(store: &ArtifactStore, req: &Request, id: &str, name: &str) -> Response {
    let art = match store.get(id) {
        Some(a) => a,
        None => return Response::error(404, &format!("unknown artifact '{id}'")),
    };
    let field = match art.fields.iter().find(|f| f.name == name) {
        Some(f) => f,
        None => {
            let have: Vec<&str> =
                art.fields.iter().map(|f| f.name.as_str()).collect();
            return Response::error(
                404,
                &format!("artifact '{id}' has no field '{name}' (holds {have:?})"),
            );
        }
    };
    // ?snapshot=K picks the series timestep (default 0, the only
    // snapshot in v1/v2 artifacts): malformed → 400, out of range → 404
    let snapshot: usize = match req.query_param("snapshot") {
        None => 0,
        Some(spec) => match spec.parse() {
            Ok(s) => s,
            Err(_) => {
                return Response::error(400, &format!("bad snapshot '{spec}'"))
            }
        },
    };
    let snapshots = art.reader.snapshot_count();
    if snapshot >= snapshots {
        return Response::error(
            404,
            &format!(
                "artifact '{id}' has no snapshot {snapshot} (holds {snapshots})"
            ),
        );
    }
    let total = field.dims[0];
    let rows = match req.query_param("rows") {
        None => 0..total,
        Some(spec) => match parse_rows(spec) {
            Ok(r) => r,
            Err(msg) => return Response::error(400, &msg),
        },
    };
    if rows.start >= rows.end || rows.end > total {
        return Response::error(
            416,
            &format!(
                "rows {}..{} unsatisfiable for field '{name}' with {total} rows",
                rows.start, rows.end
            ),
        )
        .with_header("Content-Range", format!("rows */{total}"));
    }
    let format = req.query_param("format").unwrap_or("f32");
    if format == "f32" && field.dtype != "f32" {
        return Response::error(
            400,
            &format!(
                "field '{name}' is {}; request format=raw or format=json",
                field.dtype
            ),
        );
    }
    if !matches!(format, "f32" | "raw" | "json") {
        return Response::error(
            400,
            &format!("unknown format '{format}' (expected f32, raw, or json)"),
        );
    }
    let region = match art.reader.read_region_at(snapshot, name, rows.clone()) {
        Ok(r) => r,
        Err(e) => return Response::error(500, &e.to_string()),
    };
    let dims = region.shape.dims().to_vec();
    let resp = match format {
        // JSON number arrays deflate ~5-10×, so this is the one response
        // body worth content-encoding; the raw little-endian paths carry
        // already-compressed-adjacent float bytes and stay identity
        "json" => gzip_negotiate(
            req,
            Response::json(
                200,
                format!(
                    "{{\"artifact\":\"{}\",\"field\":\"{}\",\"snapshot\":{},\
                     \"rows\":[{},{}],\
                     \"dims\":{},\"dtype\":\"{}\",\"values\":{}}}",
                    json_escape(id),
                    json_escape(name),
                    snapshot,
                    rows.start,
                    rows.end,
                    dims_json(&dims),
                    region.values.dtype(),
                    values_json(&region.values)
                ),
            ),
        ),
        // "f32" | "raw": the exact little-endian bytes `read_region_at`
        // produces — bit-identical to `sz3 extract` output
        _ => Response::octets(region.values.to_le_bytes()),
    };
    resp.with_header("X-SZ3-Dims", dims_csv(&dims))
        .with_header("X-SZ3-Dtype", region.values.dtype())
        .with_header("X-SZ3-Rows", format!("{}..{}", rows.start, rows.end))
        .with_header("X-SZ3-Snapshot", snapshot.to_string())
}

/// Did the client offer gzip? Token scan over `Accept-Encoding`, treating
/// an explicit `q=0` as refusal; no q-value ranking beyond that — gzip is
/// the only encoding we produce.
fn accepts_gzip(req: &Request) -> bool {
    let Some(v) = req.header("accept-encoding") else { return false };
    v.split(',').any(|item| {
        let mut parts = item.split(';');
        let name = parts.next().unwrap_or("").trim();
        if !name.eq_ignore_ascii_case("gzip") && name != "*" {
            return false;
        }
        !parts.any(|p| {
            let p: String = p.chars().filter(|c| !c.is_whitespace()).collect();
            p == "q=0" || p == "q=0.0" || p == "q=0.00" || p == "q=0.000"
        })
    })
}

/// Gzip `resp`'s body when the request offered it. Always stamps
/// `Vary: Accept-Encoding` (the representation is negotiated either
/// way); on encode failure the identity body is served unchanged.
fn gzip_negotiate(req: &Request, resp: Response) -> Response {
    let mut resp = resp.with_header("Vary", "Accept-Encoding");
    if !accepts_gzip(req) {
        return resp;
    }
    use std::io::Write;
    let mut enc =
        flate2::write::GzEncoder::new(Vec::new(), flate2::Compression::default());
    let encoded = enc.write_all(&resp.body).ok().and_then(|()| enc.finish().ok());
    if let Some(z) = encoded {
        resp.body = z;
        resp = resp.with_header("Content-Encoding", "gzip");
    }
    resp
}

fn raw(store: &ArtifactStore, req: &Request, id: &str) -> Response {
    let art = match store.get(id) {
        Some(a) => a,
        None => return Response::error(404, &format!("unknown artifact '{id}'")),
    };
    let spec = match req.query_param("chunk") {
        Some(s) => s,
        None => return Response::error(400, "missing required ?chunk=N"),
    };
    let n: usize = match spec.parse() {
        Ok(n) => n,
        Err(_) => return Response::error(400, &format!("bad chunk index '{spec}'")),
    };
    let entry = match art.reader.index().entries.get(n) {
        Some(e) => e.clone(),
        None => {
            return Response::error(
                404,
                &format!(
                    "chunk {n} out of range ({} entries; see the meta endpoint's \
                     chunk_map.entry)",
                    art.reader.index().entries.len()
                ),
            )
        }
    };
    // conditional GET: the chunk's index CRC-32 is a strong validator for
    // the immutable payload, so ETag = quoted crc hex and a matching
    // If-None-Match short-circuits with 304 before any payload fetch
    // (v1 artifacts carry no CRC and therefore no ETag)
    let etag = entry.crc32.map(|c| format!("\"{c:08x}\""));
    if let (Some(tag), Some(inm)) = (&etag, req.header("if-none-match")) {
        // RFC 7232 §3.2: If-None-Match uses *weak* comparison, so a
        // W/-prefixed validator (e.g. weakened by an upstream cache)
        // still matches our strong ETag
        let matches = inm.split(',').map(str::trim).any(|t| {
            let t = t.strip_prefix("W/").unwrap_or(t);
            t == tag || t == "*"
        });
        if matches {
            return Response::not_modified().with_header("ETag", tag.clone());
        }
    }
    match art.reader.chunk_payload(n) {
        Ok(bytes) => {
            let total = bytes.len();
            let range = match req.header("range") {
                Some(spec) => parse_byte_range(spec, total),
                None => ByteRange::Full,
            };
            if let ByteRange::Unsatisfiable = range {
                return Response::error(
                    416,
                    &format!("range unsatisfiable for {total}-byte chunk payload"),
                )
                .with_header("Content-Range", format!("bytes */{total}"));
            }
            let (status, body, content_range) = match range {
                ByteRange::Slice(first, last) => (
                    206,
                    bytes.get(first..=last).unwrap_or(&[]).to_vec(),
                    Some(format!("bytes {first}-{last}/{total}")),
                ),
                _ => (200, bytes, None),
            };
            let mut resp = Response::octets(body);
            resp.status = status;
            if let Some(cr) = content_range {
                resp = resp.with_header("Content-Range", cr);
            }
            let mut resp = resp
                .with_header("Accept-Ranges", "bytes")
                .with_header("X-SZ3-Field", entry.field.clone())
                .with_header("X-SZ3-Chunk", entry.chunk_index.to_string())
                .with_header("X-SZ3-Pipeline", entry.pipeline.clone())
                .with_header("X-SZ3-Snapshot", entry.snapshot.to_string())
                .with_header("X-SZ3-Delta", entry.delta.to_string())
                .with_header(
                    "X-SZ3-Rows",
                    format!("{}..{}", entry.rows.0, entry.rows.1),
                );
            if let Some(c) = entry.crc32 {
                resp = resp.with_header("X-SZ3-Crc32", format!("{c:#010x}"));
            }
            if let Some(tag) = etag {
                resp = resp.with_header("ETag", tag);
            }
            resp
        }
        Err(e) => Response::error(500, &e.to_string()),
    }
}

/// Prometheus text exposition (format 0.0.4) of the whole process-wide
/// [`obs`] registry — pipeline stages, coordinator, selector, reader,
/// cache, ingest, and HTTP families in one scrape.
fn metricsz() -> Response {
    Response::text(
        200,
        "text/plain; version=0.0.4; charset=utf-8",
        obs::render_prometheus(),
    )
}

/// Ids created over PUT become file stems, so they are restricted to a
/// filesystem- and URL-safe alphabet (and the path keywords `.`/`..`
/// are refused). Ids opened from disk are matched against the store and
/// may use any stem the filesystem allowed.
fn valid_ingest_id(id: &str) -> bool {
    !id.is_empty()
        && id.len() <= 64
        && id != "."
        && id != ".."
        && id
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'-' || b == b'_' || b == b'.')
}

/// `PUT /v1/artifacts/{id}`: claim an ingest slot (429 + `Retry-After`
/// when all are busy), compress the body through the coordinator, and
/// publish atomically. 201 on create, 200 on replace.
fn ingest(registry: &Registry, req: &Request, id: &str) -> Response {
    if !registry.writable() {
        return Response::error(
            503,
            "server is read-only; ingest requires a writable registry",
        );
    }
    if !valid_ingest_id(id) {
        return Response::error(
            400,
            &format!("artifact id '{id}' must be 1-64 chars of [A-Za-z0-9._-]"),
        );
    }
    let Some(_permit) = registry.try_begin_ingest() else {
        obs::INGEST_REJECTED_BUSY.inc();
        return Response::error(
            429,
            &format!(
                "all {} ingest slots are busy; retry shortly",
                registry.max_inflight_ingests()
            ),
        )
        .with_header("Retry-After", "1");
    };
    let t0 = Instant::now();
    let resp = ingest_with_permit(registry, req, id);
    obs::INGEST_SECONDS.observe_since(t0);
    match resp.status {
        201 => obs::INGEST_CREATED.inc(),
        200 => obs::INGEST_REPLACED.inc(),
        _ => obs::INGEST_FAILED.inc(),
    }
    resp
}

/// The ingest body after the permit is held. Framing:
/// `[u32le json_len][json params][field data]`, where the data section
/// is each field's elements as little-endian f32 in the order the
/// `fields` param lists them, and the total length must match exactly.
fn ingest_with_permit(registry: &Registry, req: &Request, id: &str) -> Response {
    let body = &req.body;
    let Some(head) = body.get(..4).and_then(|b| <[u8; 4]>::try_from(b).ok())
    else {
        return Response::error(
            400,
            "body too short for the [u32 json_len][json][data] framing",
        );
    };
    let json_len = match usize::try_from(u32::from_le_bytes(head)) {
        Ok(n) => n,
        Err(_) => return Response::error(400, "json_len does not fit usize"),
    };
    let json_end = match 4usize.checked_add(json_len) {
        Some(e) if e <= body.len() => e,
        _ => {
            return Response::error(
                400,
                &format!(
                    "json_len {json_len} overruns the {}-byte body",
                    body.len()
                ),
            )
        }
    };
    let Some(params_bytes) = body.get(4..json_end) else {
        return Response::error(400, "json params out of range");
    };
    let Ok(params_text) = std::str::from_utf8(params_bytes) else {
        return Response::error(400, "json params are not valid UTF-8");
    };
    let params = match IngestParams::parse(params_text, registry) {
        Ok(p) => p,
        Err(msg) => return Response::error(400, &msg),
    };
    let Some(per_field) = params.elems.checked_mul(4) else {
        return Response::error(400, "dims overflow the addressable size");
    };
    let Some(data_len) = per_field.checked_mul(params.fields.len()) else {
        return Response::error(400, "fields x dims overflow the addressable size");
    };
    let Some(expected) = data_len.checked_add(json_end) else {
        return Response::error(400, "framing overflows the addressable size");
    };
    if expected != body.len() {
        return Response::error(
            400,
            &format!(
                "body is {} bytes but the framing requires {expected} \
                 (4 + {json_len} json + {} fields x {} elems x 4 data bytes)",
                body.len(),
                params.fields.len(),
                params.elems
            ),
        );
    }
    let coord = match crate::coordinator::Coordinator::from_config(&params.cfg) {
        Ok(c) => c,
        Err(e) => return Response::error(400, &e.to_string()),
    };
    let mut ingest_fields = Vec::with_capacity(params.fields.len());
    let mut off = json_end;
    for name in &params.fields {
        let Some(end) = off.checked_add(per_field) else {
            return Response::error(400, "field data out of range");
        };
        let Some(data) = body.get(off..end) else {
            return Response::error(400, "field data out of range");
        };
        let mut values = Vec::with_capacity(params.elems);
        for quad in data.chunks_exact(4) {
            let Ok(b) = <[u8; 4]>::try_from(quad) else {
                return Response::error(400, "field data misaligned");
            };
            values.push(f32::from_le_bytes(b));
        }
        match Field::f32(name.clone(), &params.dims, values) {
            Ok(f) => ingest_fields.push(f),
            Err(e) => return Response::error(400, &e.to_string()),
        }
        off = end;
    }
    obs::INGEST_BYTES.add(data_len as u64);
    let (container, _report) = match coord.run_to_container(ingest_fields) {
        Ok(r) => r,
        // config/shape problems are the client's fault; anything else
        // is an internal compression failure
        Err(e @ (SzError::Config(_) | SzError::Shape(_))) => {
            return Response::error(400, &e.to_string())
        }
        Err(e) => return Response::error(500, &e.to_string()),
    };
    match registry.publish(id, &container) {
        Ok(replaced) => {
            let status = if replaced { 200 } else { 201 };
            Response::json(
                status,
                format!(
                    "{{\"id\":\"{}\",\"replaced\":{replaced},\"bytes\":{},\
                     \"generation\":{}}}",
                    json_escape(id),
                    container.len(),
                    registry.generation()
                ),
            )
        }
        Err(e) => Response::error(500, &e.to_string()),
    }
}

/// Parsed + validated ingest JSON params.
struct IngestParams {
    /// Field shape, slowest axis first.
    dims: Vec<usize>,
    /// Elements per field (∏ dims, overflow-checked).
    elems: usize,
    /// Field names, in body order.
    fields: Vec<String>,
    /// Compression config assembled from pipeline/bound/adaptive params.
    cfg: JobConfig,
}

impl IngestParams {
    /// Parse the params object; unknown keys are rejected to catch
    /// typos, exactly like the CLI's `--config` parser.
    fn parse(text: &str, registry: &Registry) -> std::result::Result<IngestParams, String> {
        let j = Json::parse(text).map_err(|e| format!("bad json params: {e}"))?;
        let Some(obj) = j.as_obj() else {
            return Err("params must be a JSON object".to_string());
        };
        let mut dims: Vec<usize> = Vec::new();
        let mut fields: Vec<String> = Vec::new();
        let mut cfg = JobConfig {
            workers: registry.store_options().workers,
            ..JobConfig::default()
        };
        for (key, val) in obj {
            match key.as_str() {
                "dims" => {
                    let Some(arr) = val.as_arr() else {
                        return Err("dims must be an array of integers".to_string());
                    };
                    for d in arr {
                        match d.as_usize() {
                            Some(d) if d > 0 => dims.push(d),
                            _ => {
                                return Err(
                                    "dims entries must be integers >= 1".to_string()
                                )
                            }
                        }
                    }
                }
                "fields" => {
                    let Some(arr) = val.as_arr() else {
                        return Err("fields must be an array of names".to_string());
                    };
                    for f in arr {
                        let Some(name) = f.as_str() else {
                            return Err("fields entries must be strings".to_string());
                        };
                        if name.is_empty() {
                            return Err("field names must be non-empty".to_string());
                        }
                        if fields.iter().any(|x| x == name) {
                            return Err(format!("duplicate field '{name}'"));
                        }
                        fields.push(name.to_string());
                    }
                }
                "pipeline" => {
                    let Some(p) = val.as_str() else {
                        return Err("pipeline must be a string".to_string());
                    };
                    cfg.pipeline = p.to_string();
                }
                "adaptive" => {
                    let Some(b) = val.as_bool() else {
                        return Err("adaptive must be a boolean".to_string());
                    };
                    cfg.adaptive = b;
                }
                "candidates" => {
                    let Some(arr) = val.as_arr() else {
                        return Err("candidates must be an array of specs".to_string());
                    };
                    for c in arr {
                        let Some(spec) = c.as_str() else {
                            return Err(
                                "candidates entries must be strings".to_string()
                            );
                        };
                        cfg.candidates.push(spec.to_string());
                    }
                }
                "chunk_elems" => {
                    match val.as_usize() {
                        Some(c) if c > 0 => cfg.chunk_elems = c,
                        _ => {
                            return Err(
                                "chunk_elems must be an integer >= 1".to_string()
                            )
                        }
                    }
                }
                "bound" => {
                    let Some(mode) = val.get("mode").and_then(|m| m.as_str())
                    else {
                        return Err(
                            "bound needs {\"mode\":..,\"value\":..}".to_string()
                        );
                    };
                    let Some(value) = val.get("value").and_then(|v| v.as_f64())
                    else {
                        return Err("bound needs a numeric value".to_string());
                    };
                    if !(value > 0.0 && value.is_finite()) {
                        return Err("bound value must be finite and > 0".to_string());
                    }
                    cfg.bound = match mode {
                        "abs" => crate::pipeline::ErrorBound::Abs(value),
                        "rel" => crate::pipeline::ErrorBound::Rel(value),
                        "pwrel" => crate::pipeline::ErrorBound::PwRel(value),
                        other => {
                            return Err(format!(
                                "unknown bound mode '{other}' (abs, rel, pwrel)"
                            ))
                        }
                    };
                }
                other => return Err(format!("unknown param '{other}'")),
            }
        }
        if dims.is_empty() {
            return Err("params must set dims".to_string());
        }
        if fields.is_empty() {
            return Err("params must set fields".to_string());
        }
        let mut elems = 1usize;
        for d in &dims {
            elems = elems
                .checked_mul(*d)
                .ok_or_else(|| "dims overflow the addressable size".to_string())?;
        }
        Ok(IngestParams { dims, elems, fields, cfg })
    }
}

/// `DELETE /v1/artifacts/{id}`: unpublish + delete the file. In-flight
/// reads on older snapshots are unaffected.
fn delete_artifact(registry: &Registry, id: &str) -> Response {
    if !registry.writable() {
        return Response::error(
            503,
            "server is read-only; delete requires a writable registry",
        );
    }
    match registry.remove(id) {
        Ok(true) => Response::json(
            200,
            format!(
                "{{\"id\":\"{}\",\"deleted\":true,\"generation\":{}}}",
                json_escape(id),
                registry.generation()
            ),
        ),
        Ok(false) => Response::error(404, &format!("unknown artifact '{id}'")),
        Err(e) => Response::error(500, &e.to_string()),
    }
}

/// `POST /v1/admin/rescan`: reconcile the serving set with the
/// directory (pick up out-of-band files, drop vanished ones).
fn rescan(registry: &Registry) -> Response {
    if !registry.writable() {
        return Response::error(
            503,
            "server is read-only; rescan requires a writable registry",
        );
    }
    match registry.rescan() {
        Ok((added, dropped, kept)) => Response::json(
            200,
            format!(
                "{{\"added\":{added},\"dropped\":{dropped},\"kept\":{kept},\
                 \"generation\":{}}}",
                registry.generation()
            ),
        ),
        Err(e) => Response::error(500, &e.to_string()),
    }
}

/// Outcome of parsing a `Range:` header against a body of `total` bytes.
enum ByteRange {
    /// No range header, or one we ignore (malformed, multi-range, or a
    /// unit other than bytes) — RFC 7233 says serve the full 200.
    Full,
    /// Single satisfiable range: inclusive first/last byte positions.
    Slice(usize, usize),
    /// Syntactically valid but no byte overlaps the body → 416.
    Unsatisfiable,
}

/// Parse a single-range `bytes=` specifier. Supported forms: `bytes=a-b`
/// (inclusive), `bytes=a-` (from `a` to the end), and `bytes=-n` (final
/// `n` bytes). Multi-range and malformed specs fall back to `Full`;
/// a first byte at or past the end — or an empty suffix — is
/// `Unsatisfiable`.
fn parse_byte_range(spec: &str, total: usize) -> ByteRange {
    let Some(ranges) = spec.strip_prefix("bytes=") else {
        return ByteRange::Full;
    };
    let ranges = ranges.trim();
    if ranges.contains(',') {
        return ByteRange::Full;
    }
    let Some((a, b)) = ranges.split_once('-') else {
        return ByteRange::Full;
    };
    let (a, b) = (a.trim(), b.trim());
    if a.is_empty() {
        // suffix form "-n": the final n bytes
        let Ok(tail) = b.parse::<usize>() else {
            return ByteRange::Full;
        };
        if tail == 0 || total == 0 {
            return ByteRange::Unsatisfiable;
        }
        return ByteRange::Slice(
            total.saturating_sub(tail),
            total.saturating_sub(1),
        );
    }
    let Ok(first) = a.parse::<usize>() else {
        return ByteRange::Full;
    };
    if first >= total {
        return ByteRange::Unsatisfiable;
    }
    let last = if b.is_empty() {
        total.saturating_sub(1)
    } else {
        match b.parse::<usize>() {
            Ok(last) => last.min(total.saturating_sub(1)),
            Err(_) => return ByteRange::Full,
        }
    };
    if last < first {
        // inverted range is syntactically invalid — ignore the header
        return ByteRange::Full;
    }
    ByteRange::Slice(first, last)
}

fn statsz(store: &ArtifactStore, stats: &ServerStats) -> Response {
    let cache = store.cache();
    let artifacts: Vec<String> = store
        .artifacts()
        .iter()
        .map(|a| {
            // request-driven counters only: the startup CRC sweep and
            // dtype peeks are baselined out
            let s = a.request_stats();
            format!(
                "\"{}\":{{\"chunks_fetched\":{},\"bytes_fetched\":{},\
                 \"crc_verified\":{},\"chunks_decoded\":{},\"cache_hits\":{},\
                 \"delta_applied\":{}}}",
                json_escape(&a.id),
                s.chunks_fetched,
                s.bytes_fetched,
                s.crc_verified,
                s.chunks_decoded,
                s.cache_hits,
                s.delta_applied
            )
        })
        .collect();
    let endpoints: Vec<String> = stats
        .summaries()
        .iter()
        .map(|(label, s)| {
            format!(
                "\"{label}\":{{\"count\":{},\"mean_us\":{},\"p50_us\":{},\
                 \"p99_us\":{},\"max_us\":{}}}",
                s.count, s.mean_us, s.p50_us, s.p99_us, s.max_us
            )
        })
        .collect();
    let buckets: Vec<String> = super::stats::bucket_bounds_us()
        .iter()
        .map(|b| b.to_string())
        .collect();
    Response::json(
        200,
        format!(
            "{{\"uptime_s\":{:.1},\
             \"cache\":{{\"budget_bytes\":{},\"bytes\":{},\"entries\":{}}},\
             \"artifacts\":{{{}}},\"endpoints\":{{{}}},\
             \"latency_buckets_us\":[{}]}}",
            stats.uptime_s(),
            cache.budget(),
            cache.bytes(),
            cache.len(),
            artifacts.join(","),
            endpoints.join(","),
            buckets.join(",")
        ),
    )
}

fn dims_json(dims: &[usize]) -> String {
    let parts: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
    format!("[{}]", parts.join(","))
}

fn dims_csv(dims: &[usize]) -> String {
    let parts: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
    parts.join(",")
}

/// Values as a JSON number array; non-finite floats (possible in source
/// data, not representable in JSON) serialize as `null`.
fn values_json(values: &FieldValues) -> String {
    fn float<T: std::fmt::Display + Copy>(out: &mut String, x: T, finite: bool) {
        if finite {
            out.push_str(&x.to_string());
        } else {
            out.push_str("null");
        }
    }
    let mut out = String::from("[");
    match values {
        FieldValues::F32(v) => {
            for (i, x) in v.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                float(&mut out, *x, x.is_finite());
            }
        }
        FieldValues::F64(v) => {
            for (i, x) in v.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                float(&mut out, *x, x.is_finite());
            }
        }
        FieldValues::I32(v) => {
            for (i, x) in v.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&x.to_string());
            }
        }
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobConfig, Json};
    use crate::coordinator::Coordinator;
    use crate::data::Field;
    use crate::pipeline::ErrorBound;
    use crate::reader::{ContainerReader, FileSource};
    use crate::util::{prop, rng::Pcg32};
    use std::io::Cursor;
    use std::sync::Arc;

    /// Read-only registry with one artifact "demo": 24×12×12, 3
    /// rows/chunk → 8 chunks.
    fn demo_store() -> (Registry, Vec<u8>) {
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(1e-3),
            workers: 2,
            chunk_elems: 3 * 144,
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let mut rng = Pcg32::seeded(4242);
        let dims = [24usize, 12, 12];
        let field =
            Field::f32("density", &dims, prop::smooth_field(&mut rng, &dims)).unwrap();
        let (artifact, _) = coord.run_to_container(vec![field]).unwrap();
        let mut store = ArtifactStore::new(8 << 20);
        let reader = ContainerReader::new(Box::new(
            FileSource::new(Cursor::new(artifact.clone())).unwrap(),
        ))
        .unwrap()
        .with_workers(2);
        let len = artifact.len() as u64;
        store.register("demo".to_string(), reader, len).unwrap();
        (Registry::read_only(Arc::new(store)), artifact)
    }

    fn get(registry: &Registry, target: &str) -> Response {
        let stats = ServerStats::new();
        dispatch(registry, &stats, &Request::get(target))
    }

    #[test]
    fn list_and_meta_describe_the_artifact() {
        let (store, _) = demo_store();
        let resp = get(&store, "/v1/artifacts");
        assert_eq!(resp.status, 200);
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("id").unwrap().as_str(), Some("demo"));
        assert_eq!(arts[0].get("chunks").unwrap().as_usize(), Some(8));

        let resp = get(&store, "/v1/artifacts/demo");
        assert_eq!(resp.status, 200);
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let fields = j.get("fields").unwrap().as_arr().unwrap();
        assert_eq!(fields.len(), 1);
        let f = &fields[0];
        assert_eq!(f.get("name").unwrap().as_str(), Some("density"));
        assert_eq!(f.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(f.get("chunks").unwrap().as_usize(), Some(8));
        let map = f.get("chunk_map").unwrap().as_arr().unwrap();
        assert_eq!(map.len(), 8);
        assert_eq!(map[0].get("rows").unwrap().as_arr().unwrap().len(), 2);
        assert!(map[0].get("crc32").unwrap().as_f64().is_some(), "v2 carries crcs");
        // the chunk map reports the canonical per-chunk pipeline spec
        let canon = crate::pipeline::canonical("sz3-lr").unwrap();
        assert_eq!(map[0].get("pipeline").unwrap().as_str(), Some(canon.as_str()));
    }

    #[test]
    fn roi_bytes_match_read_region_exactly() {
        let (store, artifact) = demo_store();
        let resp = get(&store, "/v1/artifacts/demo/fields/density?rows=7..11");
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("X-SZ3-Dims"), Some("4,12,12"));
        assert_eq!(resp.header("X-SZ3-Dtype"), Some("f32"));
        // the acceptance bar: exactly the bytes read_region produces
        let oracle = ContainerReader::from_slice(&artifact)
            .unwrap()
            .read_region("density", 7..11)
            .unwrap();
        assert_eq!(resp.body, oracle.values.to_le_bytes());
        // and only the overlapping chunks were decoded for it
        let snap = store.snapshot();
        let served = snap.get("demo").unwrap().reader.stats();
        assert_eq!(served.chunks_decoded, 2, "rows 7..11 span 2 of 8 chunks");
    }

    #[test]
    fn roi_json_format_parses_and_matches() {
        let (store, artifact) = demo_store();
        let resp =
            get(&store, "/v1/artifacts/demo/fields/density?rows=0..1&format=json");
        assert_eq!(resp.status, 200);
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("dtype").unwrap().as_str(), Some("f32"));
        let vals = j.get("values").unwrap().as_arr().unwrap();
        assert_eq!(vals.len(), 144);
        let oracle = ContainerReader::from_slice(&artifact)
            .unwrap()
            .read_region("density", 0..1)
            .unwrap();
        if let FieldValues::F32(v) = &oracle.values {
            assert!((vals[0].as_f64().unwrap() - v[0] as f64).abs() < 1e-6);
        } else {
            panic!("demo field is f32");
        }
    }

    #[test]
    fn roi_json_gzips_when_accepted() {
        let (store, _) = demo_store();
        let stats = ServerStats::new();
        let target = "/v1/artifacts/demo/fields/density?rows=0..4&format=json";
        // identity baseline: negotiated header present, body plain JSON
        let plain = get(&store, target);
        assert_eq!(plain.status, 200);
        assert_eq!(plain.header("Vary"), Some("Accept-Encoding"));
        assert_eq!(plain.header("Content-Encoding"), None);

        let mut req = Request::get(target);
        req.headers
            .push(("accept-encoding".to_string(), "br, gzip;q=0.8".to_string()));
        let resp = dispatch(&store, &stats, &req);
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("Content-Encoding"), Some("gzip"));
        assert_eq!(resp.header("Vary"), Some("Accept-Encoding"));
        assert_eq!(resp.header("Content-Type"), Some("application/json"));
        assert!(
            resp.body.len() < plain.body.len() / 2,
            "json should deflate well: {} vs {}",
            resp.body.len(),
            plain.body.len()
        );
        // body is real gzip framing that decodes back to the identity json
        use std::io::Read;
        let mut dec = flate2::read::GzDecoder::new(resp.body.as_slice());
        let mut out = Vec::new();
        dec.read_to_end(&mut out).unwrap();
        assert_eq!(out, plain.body);

        // an explicit q=0 refusal and non-gzip offers stay identity
        for ae in ["gzip;q=0", "identity", "br"] {
            let mut req = Request::get(target);
            req.headers.push(("accept-encoding".to_string(), ae.to_string()));
            let resp = dispatch(&store, &stats, &req);
            assert_eq!(resp.header("Content-Encoding"), None, "ae={ae}");
        }
        // raw responses never negotiate an encoding
        let mut req =
            Request::get("/v1/artifacts/demo/fields/density?rows=0..4&format=raw");
        req.headers.push(("accept-encoding".to_string(), "gzip".to_string()));
        let resp = dispatch(&store, &stats, &req);
        assert_eq!(resp.header("Content-Encoding"), None);
    }

    #[test]
    fn error_matrix_404_416_400_405() {
        let (store, _) = demo_store();
        // unknown artifact / field / route
        assert_eq!(get(&store, "/v1/artifacts/nope").status, 404);
        assert_eq!(get(&store, "/v1/artifacts/nope/fields/density").status, 404);
        assert_eq!(get(&store, "/v1/artifacts/demo/fields/nope").status, 404);
        assert_eq!(get(&store, "/v2/artifacts").status, 404);
        // unsatisfiable ranges: out of bounds, empty, inverted
        for bad in ["9..99", "5..5", "9..7", "24..30"] {
            let resp =
                get(&store, &format!("/v1/artifacts/demo/fields/density?rows={bad}"));
            assert_eq!(resp.status, 416, "rows={bad}");
            assert_eq!(resp.header("Content-Range"), Some("rows */24"));
        }
        // malformed parameters
        for bad in ["abc", "1..x", "1-5", ""] {
            let resp =
                get(&store, &format!("/v1/artifacts/demo/fields/density?rows={bad}"));
            assert_eq!(resp.status, 400, "rows={bad}");
        }
        let resp = get(&store, "/v1/artifacts/demo/fields/density?format=xml");
        assert_eq!(resp.status, 400);
        // raw chunk errors
        assert_eq!(get(&store, "/v1/artifacts/demo/raw").status, 400);
        assert_eq!(get(&store, "/v1/artifacts/demo/raw?chunk=zap").status, 400);
        assert_eq!(get(&store, "/v1/artifacts/demo/raw?chunk=99").status, 404);
        // method guard
        let stats = ServerStats::new();
        let mut post = Request::get("/v1/artifacts");
        post.method = "POST".to_string();
        let resp = dispatch(&store, &stats, &post);
        assert_eq!(resp.status, 405);
        assert_eq!(resp.header("Allow"), Some("GET, HEAD"));
        // every error body is the uniform JSON shape
        let resp = get(&store, "/v1/artifacts/nope");
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("error").unwrap().get("status").unwrap().as_usize(), Some(404));
    }

    #[test]
    fn raw_chunk_passthrough_with_provenance_headers() {
        let (store, artifact) = demo_store();
        let resp = get(&store, "/v1/artifacts/demo/raw?chunk=3");
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("X-SZ3-Field"), Some("density"));
        assert!(resp.header("X-SZ3-Pipeline").is_some());
        assert!(resp.header("X-SZ3-Crc32").is_some(), "v2 chunk carries its crc");
        let oracle = ContainerReader::from_slice(&artifact).unwrap();
        assert_eq!(resp.body, oracle.chunk_payload(3).unwrap());
        // the payload is a self-describing SZ3R stream a client can decode
        let decoded = crate::pipeline::decompress_any(&resp.body).unwrap();
        assert_eq!(decoded.shape.dims()[1..], [12, 12]);
        // the advertised pipeline is the canonical spec the index records
        assert_eq!(
            resp.header("X-SZ3-Pipeline"),
            Some(crate::pipeline::canonical("sz3-lr").unwrap().as_str())
        );
    }

    #[test]
    fn conditional_get_on_raw_chunks_via_etag() {
        let (store, artifact) = demo_store();
        let stats = ServerStats::new();
        let resp = get(&store, "/v1/artifacts/demo/raw?chunk=2");
        assert_eq!(resp.status, 200);
        let etag = resp.header("ETag").expect("v2+ chunks carry an ETag").to_string();
        // ETag is the chunk CRC-32, quoted hex
        let meta = crate::container::read_index_meta(&artifact).unwrap();
        let crc = meta.index.entries[2].crc32.unwrap();
        assert_eq!(etag, format!("\"{crc:08x}\""));
        // matching If-None-Match → 304 with an empty body and the ETag
        let mut req = Request::get("/v1/artifacts/demo/raw?chunk=2");
        req.headers.push(("if-none-match".to_string(), etag.clone()));
        let resp = dispatch(&store, &stats, &req);
        assert_eq!(resp.status, 304);
        assert!(resp.body.is_empty());
        assert_eq!(resp.header("ETag"), Some(etag.as_str()));
        // list form, weak-validator form, and wildcard also match
        let mut req = Request::get("/v1/artifacts/demo/raw?chunk=2");
        req.headers
            .push(("if-none-match".to_string(), format!("\"deadbeef\", {etag}")));
        assert_eq!(dispatch(&store, &stats, &req).status, 304);
        let mut req = Request::get("/v1/artifacts/demo/raw?chunk=2");
        req.headers.push(("if-none-match".to_string(), format!("W/{etag}")));
        assert_eq!(dispatch(&store, &stats, &req).status, 304, "weak comparison");
        let mut req = Request::get("/v1/artifacts/demo/raw?chunk=2");
        req.headers.push(("if-none-match".to_string(), "*".to_string()));
        assert_eq!(dispatch(&store, &stats, &req).status, 304);
        // a stale validator still gets the full payload
        let mut req = Request::get("/v1/artifacts/demo/raw?chunk=2");
        req.headers.push(("if-none-match".to_string(), "\"00000000\"".to_string()));
        let resp = dispatch(&store, &stats, &req);
        assert_eq!(resp.status, 200);
        assert!(!resp.body.is_empty());
    }

    /// Registry with one 3-snapshot delta series artifact "ts".
    fn series_store() -> (Registry, Vec<u8>) {
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(1e-3),
            workers: 2,
            chunk_elems: 3 * 144,
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let snaps = crate::container::fixtures::smooth_series(
            616,
            &[12, 12, 12],
            3,
            0.01,
            "rho",
        );
        let (artifact, _) = coord.run_series_to_container(snaps, true).unwrap();
        let mut store = ArtifactStore::new(8 << 20);
        let reader = ContainerReader::new(Box::new(
            FileSource::new(Cursor::new(artifact.clone())).unwrap(),
        ))
        .unwrap()
        .with_workers(2);
        let len = artifact.len() as u64;
        store.register("ts".to_string(), reader, len).unwrap();
        (Registry::read_only(Arc::new(store)), artifact)
    }

    #[test]
    fn snapshot_param_contract_and_series_metadata() {
        let (store, artifact) = series_store();
        // list advertises the snapshot count
        let resp = get(&store, "/v1/artifacts");
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let art = &j.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(art.get("snapshots").unwrap().as_usize(), Some(3));
        // meta lists ids and tags, and the chunk map carries snapshot/delta
        let resp = get(&store, "/v1/artifacts/ts");
        assert_eq!(resp.status, 200);
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let snaps = j.get("snapshots").unwrap().as_arr().unwrap();
        assert_eq!(snaps.len(), 3);
        assert_eq!(snaps[1].get("id").unwrap().as_usize(), Some(1));
        assert_eq!(snaps[1].get("tag").unwrap().as_str(), Some("t1"));
        let map = j.get("fields").unwrap().as_arr().unwrap()[0]
            .get("chunk_map")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(map.len(), 12, "4 chunks x 3 snapshots");
        assert!(map[0].get("snapshot").unwrap().as_usize().is_some());
        // valid snapshots serve the exact read_region_at bytes
        for snap in 0..3 {
            let resp = get(
                &store,
                &format!("/v1/artifacts/ts/fields/rho?rows=2..7&snapshot={snap}"),
            );
            assert_eq!(resp.status, 200, "snapshot={snap}");
            assert_eq!(resp.header("X-SZ3-Snapshot"), Some(format!("{snap}")).as_deref());
            let oracle = ContainerReader::from_slice(&artifact)
                .unwrap()
                .read_region_at(snap, "rho", 2..7)
                .unwrap();
            assert_eq!(resp.body, oracle.values.to_le_bytes(), "snapshot={snap}");
        }
        // out of range → 404; malformed → 400
        assert_eq!(get(&store, "/v1/artifacts/ts/fields/rho?snapshot=3").status, 404);
        assert_eq!(get(&store, "/v1/artifacts/ts/fields/rho?snapshot=99").status, 404);
        for bad in ["abc", "-1", "1.5", ""] {
            let resp =
                get(&store, &format!("/v1/artifacts/ts/fields/rho?snapshot={bad}"));
            assert_eq!(resp.status, 400, "snapshot={bad}");
        }
        // the default (no param) is snapshot 0 — same bytes
        let a = get(&store, "/v1/artifacts/ts/fields/rho?rows=0..3");
        let b = get(&store, "/v1/artifacts/ts/fields/rho?rows=0..3&snapshot=0");
        assert_eq!(a.body, b.body);
        // single-snapshot artifacts accept only snapshot=0
        let (demo, _) = demo_store();
        assert_eq!(
            get(&demo, "/v1/artifacts/demo/fields/density?snapshot=0").status,
            200
        );
        assert_eq!(
            get(&demo, "/v1/artifacts/demo/fields/density?snapshot=1").status,
            404
        );
    }

    #[test]
    fn statsz_reflects_cache_hits_on_repeat_queries() {
        let (store, _) = demo_store();
        let stats = ServerStats::new();
        let req = Request::get("/v1/artifacts/demo/fields/density?rows=0..3");
        dispatch(&store, &stats, &req);
        dispatch(&store, &stats, &req);
        let resp = dispatch(&store, &stats, &Request::get("/statsz"));
        assert_eq!(resp.status, 200);
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let demo = j.get("artifacts").unwrap().get("demo").unwrap();
        assert_eq!(demo.get("chunks_decoded").unwrap().as_usize(), Some(1));
        assert_eq!(demo.get("cache_hits").unwrap().as_usize(), Some(1));
        let roi = j.get("endpoints").unwrap().get("roi").unwrap();
        assert_eq!(roi.get("count").unwrap().as_usize(), Some(2));
        assert!(j.get("cache").unwrap().get("bytes").unwrap().as_usize().unwrap() > 0);
        // healthz is alive too
        let resp = dispatch(&store, &stats, &Request::get("/healthz"));
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("status").unwrap().as_str(), Some("ok"));
    }

    #[test]
    fn statsz_reports_latency_bucket_bounds() {
        let (store, _) = demo_store();
        let resp = get(&store, "/statsz");
        assert_eq!(resp.status, 200);
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let buckets = j.get("latency_buckets_us").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), super::super::stats::N_BUCKETS);
        assert_eq!(buckets[0].as_usize(), Some(2));
        assert_eq!(buckets[1].as_usize(), Some(4), "log2-spaced bounds");
    }

    #[test]
    fn metricsz_serves_prometheus_exposition() {
        let (store, _) = demo_store();
        // drive a request through dispatch first so HTTP counters move
        let stats = ServerStats::new();
        dispatch(&store, &stats, &Request::get("/v1/artifacts"));
        let resp = dispatch(&store, &stats, &Request::get("/metricsz"));
        assert_eq!(resp.status, 200);
        assert_eq!(
            resp.header("Content-Type"),
            Some("text/plain; version=0.0.4; charset=utf-8")
        );
        let text = std::str::from_utf8(&resp.body).unwrap();
        // every family declares TYPE before its samples, and the demo
        // compression above populated the pipeline-stage families
        assert!(text.contains("# TYPE sz3_stage_seconds_total counter"));
        assert!(text.contains("# TYPE sz3_http_requests_total counter"));
        let families =
            text.lines().filter(|l| l.starts_with("# TYPE ")).count();
        assert!(families >= 15, "expected >= 15 metric families, got {families}");
    }

    #[test]
    fn range_requests_slice_raw_chunks() {
        let (store, artifact) = demo_store();
        let stats = ServerStats::new();
        let full = ContainerReader::from_slice(&artifact)
            .unwrap()
            .chunk_payload(1)
            .unwrap();
        let with_range = |spec: &str| {
            let mut req = Request::get("/v1/artifacts/demo/raw?chunk=1");
            req.headers.push(("range".to_string(), spec.to_string()));
            dispatch(&store, &stats, &req)
        };
        // plain GET advertises range support and serves everything
        let resp = get(&store, "/v1/artifacts/demo/raw?chunk=1");
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("Accept-Ranges"), Some("bytes"));
        let total = full.len();
        // closed range
        let resp = with_range("bytes=0-3");
        assert_eq!(resp.status, 206);
        assert_eq!(resp.body, full[0..=3]);
        assert_eq!(
            resp.header("Content-Range"),
            Some(format!("bytes 0-3/{total}").as_str())
        );
        // open-ended range
        let resp = with_range("bytes=4-");
        assert_eq!(resp.status, 206);
        assert_eq!(resp.body, full[4..]);
        // suffix range: the final 5 bytes
        let resp = with_range("bytes=-5");
        assert_eq!(resp.status, 206);
        assert_eq!(resp.body, full[total - 5..]);
        assert_eq!(
            resp.header("Content-Range"),
            Some(format!("bytes {}-{}/{total}", total - 5, total - 1).as_str())
        );
        // a last byte past the end is clamped, not rejected (RFC 7233)
        let resp = with_range(&format!("bytes=2-{}", total + 99));
        assert_eq!(resp.status, 206);
        assert_eq!(resp.body, full[2..]);
        // unsatisfiable: first byte at/past the end
        let resp = with_range(&format!("bytes={total}-"));
        assert_eq!(resp.status, 416);
        assert_eq!(
            resp.header("Content-Range"),
            Some(format!("bytes */{total}").as_str())
        );
        // malformed and multi-range specs are ignored → full 200
        for spec in ["bytes=a-b", "bytes=5-2", "bytes=0-3,7-9", "items=0-3"] {
            let resp = with_range(spec);
            assert_eq!(resp.status, 200, "range spec {spec}");
            assert_eq!(resp.body, full, "range spec {spec}");
        }
        // conditional GET wins over Range: matching validator still 304s
        let etag = get(&store, "/v1/artifacts/demo/raw?chunk=1")
            .header("ETag")
            .unwrap()
            .to_string();
        let mut req = Request::get("/v1/artifacts/demo/raw?chunk=1");
        req.headers.push(("range".to_string(), "bytes=0-3".to_string()));
        req.headers.push(("if-none-match".to_string(), etag));
        assert_eq!(dispatch(&store, &stats, &req).status, 304);
    }

    // ---- write path -------------------------------------------------

    fn method_req(method: &str, target: &str) -> Request {
        let mut req = Request::get(target);
        req.method = method.to_string();
        req
    }

    /// Frame an ingest body: `[u32le json_len][json][data]`.
    fn framed(params: &str, data: &[u8]) -> Vec<u8> {
        let mut body = (params.len() as u32).to_le_bytes().to_vec();
        body.extend_from_slice(params.as_bytes());
        body.extend_from_slice(data);
        body
    }

    fn put_req(id: &str, body: Vec<u8>) -> Request {
        let mut req = method_req("PUT", &format!("/v1/artifacts/{id}"));
        req.body = body;
        req
    }

    /// Writable registry rooted at a fresh temp dir.
    fn writable_registry(tag: &str) -> (std::path::PathBuf, Registry) {
        let dir = std::env::temp_dir()
            .join(format!("sz3_handlers_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let reg =
            Registry::open_dir(&dir, &crate::server::StoreOptions::default())
                .unwrap();
        (dir, reg)
    }

    const WAVE_PARAMS: &str = "{\"dims\":[8,64],\"fields\":[\"rho\"],\
         \"pipeline\":\"sz3-lr\",\"bound\":{\"mode\":\"abs\",\"value\":0.001},\
         \"chunk_elems\":256}";

    fn wave_values(base: f32) -> Vec<f32> {
        (0..512).map(|i| base + (i as f32) * 0.01).collect()
    }

    fn le_bytes(values: &[f32]) -> Vec<u8> {
        values.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    #[test]
    fn ingest_delete_rescan_lifecycle_over_dispatch() {
        let (dir, reg) = writable_registry("lifecycle");
        let stats = ServerStats::new();
        let values = wave_values(0.0);

        // create → 201, replaced:false
        let resp = dispatch(
            &reg,
            &stats,
            &put_req("wave", framed(WAVE_PARAMS, &le_bytes(&values))),
        );
        assert_eq!(resp.status, 201, "{}", String::from_utf8_lossy(&resp.body));
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("replaced").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("generation").unwrap().as_usize(), Some(1));
        assert_eq!(get(&reg, "/v1/artifacts/wave").status, 200);

        // the published artifact serves the data back within the bound
        let resp = get(&reg, "/v1/artifacts/wave/fields/rho?rows=0..8");
        assert_eq!(resp.status, 200);
        let served: Vec<f32> = resp
            .body
            .chunks_exact(4)
            .map(|q| f32::from_le_bytes(q.try_into().unwrap()))
            .collect();
        assert_eq!(served.len(), values.len());
        for (got, want) in served.iter().zip(&values) {
            assert!((got - want).abs() <= 1e-3 + 1e-6, "{got} vs {want}");
        }

        // replace → 200, replaced:true, and the new bytes are served
        let resp = dispatch(
            &reg,
            &stats,
            &put_req("wave", framed(WAVE_PARAMS, &le_bytes(&wave_values(50.0)))),
        );
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("replaced").unwrap().as_bool(), Some(true));
        let resp = get(&reg, "/v1/artifacts/wave/fields/rho?rows=0..1");
        let first = f32::from_le_bytes(resp.body[..4].try_into().unwrap());
        assert!((first - 50.0).abs() <= 1e-3 + 1e-6, "replaced data served");

        // delete → 200, then 404 both for reads and a second delete
        let resp =
            dispatch(&reg, &stats, &method_req("DELETE", "/v1/artifacts/wave"));
        assert_eq!(resp.status, 200);
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("deleted").unwrap().as_bool(), Some(true));
        assert_eq!(get(&reg, "/v1/artifacts/wave").status, 404);
        assert_eq!(
            dispatch(&reg, &stats, &method_req("DELETE", "/v1/artifacts/wave"))
                .status,
            404
        );

        // rescan of the now-empty dir reports a clean zero delta
        let resp =
            dispatch(&reg, &stats, &method_req("POST", "/v1/admin/rescan"));
        assert_eq!(resp.status, 200);
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("added").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("dropped").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("kept").unwrap().as_usize(), Some(0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ingest_rejects_malformed_bodies_and_ids() {
        let (dir, reg) = writable_registry("badput");
        let stats = ServerStats::new();
        let data = le_bytes(&wave_values(0.0));
        let cases: Vec<(&str, Vec<u8>)> = vec![
            ("truncated prefix", vec![1, 2]),
            ("json_len overrun", {
                let mut b = 999u32.to_le_bytes().to_vec();
                b.extend_from_slice(b"{}");
                b
            }),
            ("bad json", framed("{not json", &data)),
            ("non-object params", framed("[1,2]", &data)),
            ("unknown key", framed("{\"dims\":[8,64],\"nope\":1}", &data)),
            ("missing fields", framed("{\"dims\":[8,64]}", &data)),
            (
                "zero dim",
                framed("{\"dims\":[0,64],\"fields\":[\"rho\"]}", &data),
            ),
            (
                "duplicate field",
                framed("{\"dims\":[8,64],\"fields\":[\"rho\",\"rho\"]}", &data),
            ),
            ("short data", framed(WAVE_PARAMS, &data[..100])),
            (
                "bad bound mode",
                framed(
                    "{\"dims\":[8,64],\"fields\":[\"rho\"],\
                     \"bound\":{\"mode\":\"nope\",\"value\":0.1}}",
                    &data,
                ),
            ),
            (
                "zero bound",
                framed(
                    "{\"dims\":[8,64],\"fields\":[\"rho\"],\
                     \"bound\":{\"mode\":\"abs\",\"value\":0}}",
                    &data,
                ),
            ),
            (
                "unknown pipeline",
                framed(
                    "{\"dims\":[8,64],\"fields\":[\"rho\"],\
                     \"pipeline\":\"zzz\"}",
                    &data,
                ),
            ),
        ];
        for (what, body) in cases {
            let resp = dispatch(&reg, &stats, &put_req("w", body));
            assert_eq!(resp.status, 400, "{what}");
        }
        // ids that are not filesystem-safe stems are refused up front
        let long_id = "x".repeat(65);
        for bad_id in [".", "..", "a b", "a\u{e9}b", long_id.as_str()] {
            let resp = dispatch(
                &reg,
                &stats,
                &put_req(bad_id, framed(WAVE_PARAMS, &data)),
            );
            assert_eq!(resp.status, 400, "id {bad_id:?}");
        }
        // nothing was published and nothing leaked onto disk
        assert_eq!(reg.generation(), 0);
        assert!(reg.snapshot().artifacts().is_empty());
        let leftovers: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert!(leftovers.is_empty(), "no debris: {leftovers:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn mutations_on_read_only_registry_are_503() {
        let (reg, _) = demo_store();
        let stats = ServerStats::new();
        let put = put_req(
            "demo",
            framed(WAVE_PARAMS, &le_bytes(&wave_values(0.0))),
        );
        assert_eq!(dispatch(&reg, &stats, &put).status, 503);
        assert_eq!(
            dispatch(&reg, &stats, &method_req("DELETE", "/v1/artifacts/demo"))
                .status,
            503
        );
        assert_eq!(
            dispatch(&reg, &stats, &method_req("POST", "/v1/admin/rescan"))
                .status,
            503
        );
        // the read path is untouched
        assert_eq!(get(&reg, "/v1/artifacts/demo").status, 200);
    }

    #[test]
    fn busy_ingest_answers_429_with_retry_after() {
        let (dir, reg) = writable_registry("busy");
        let reg = reg.with_max_inflight_ingests(1);
        let stats = ServerStats::new();
        let body = framed(WAVE_PARAMS, &le_bytes(&wave_values(0.0)));
        let permit = reg.try_begin_ingest().unwrap();
        let resp = dispatch(&reg, &stats, &put_req("wave", body.clone()));
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("Retry-After"), Some("1"));
        drop(permit);
        let resp = dispatch(&reg, &stats, &put_req("wave", body));
        assert_eq!(resp.status, 201, "slot freed by the RAII permit");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn write_route_method_guards() {
        let (reg, _) = demo_store();
        let stats = ServerStats::new();
        let resp =
            dispatch(&reg, &stats, &method_req("PATCH", "/v1/artifacts/demo"));
        assert_eq!(resp.status, 405);
        assert_eq!(resp.header("Allow"), Some("GET, HEAD, PUT, DELETE"));
        let resp =
            dispatch(&reg, &stats, &method_req("GET", "/v1/admin/rescan"));
        assert_eq!(resp.status, 405);
        assert_eq!(resp.header("Allow"), Some("POST"));
    }
}
