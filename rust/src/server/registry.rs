//! Epoch-pointer artifact registry — the serve path's write side.
//!
//! A [`Registry`] wraps an immutable [`ArtifactStore`] behind a swapped
//! `Arc` pointer (the hand-rolled equivalent of `ArcSwap`, which is
//! unavailable offline): readers call [`Registry::snapshot`] once per
//! request and keep decoding from that store no matter what writers do —
//! zero stall, zero torn reads. Writers serialize on a dedicated
//! mutation lock, build a **successor** store that shares every
//! unchanged artifact `Arc`, and swap the pointer together with a
//! monotonically increasing generation counter. An in-flight request
//! started on generation *g* finishes bit-identical to generation *g*
//! even if ten replaces land meanwhile.
//!
//! # Publish protocol (crash-safe)
//!
//! 1. write the packed container to `.{id}.ingest-{pid}-{seq}` — a
//!    non-`.sz3c` name that [`Registry::rescan`] never picks up;
//! 2. `fsync` the staged file;
//! 3. open and (optionally) CRC-verify a reader **from the staged
//!    path** — the file descriptor survives the rename;
//! 4. `rename` to `{id}.sz3c` (atomic within the directory) and
//!    best-effort `fsync` the directory;
//! 5. swap the epoch pointer and bump the generation.
//!
//! A crash or error anywhere before step 4 leaves only a staged temp
//! file, which a drop guard deletes on the error path and which rescan
//! ignores by construction; the registry generation does not move.
//!
//! # Cache hygiene
//!
//! Every registration gets a unique cache scope (see
//! [`Artifact::scope`]), so a replacement can never poison reads with
//! its predecessor's decoded chunks. Retiring an artifact evicts its
//! scope from the shared [`crate::reader::ChunkCache`] purely to return
//! budget to live artifacts.

use super::{Artifact, ArtifactStore, StoreOptions};
use crate::error::{Result, SzError};
use crate::reader::ContainerReader;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Monotonic sequence making staged temp-file names unique per process.
static TEMP_SEQ: AtomicU64 = AtomicU64::new(1);

/// Default cap on concurrent ingests for writable registries.
const DEFAULT_MAX_INGESTS: usize = 2;

/// Epoch-pointer registry: an immutable [`ArtifactStore`] snapshot
/// swapped atomically under a generation counter, plus the bounded
/// ingest-slot pool that back-pressures `PUT` traffic.
pub struct Registry {
    /// Serving directory; `None` makes the registry read-only.
    dir: Option<PathBuf>,
    /// How artifacts are opened (cache budget, workers, verify).
    opts: StoreOptions,
    /// The epoch pointer: current store and its generation, always
    /// swapped together so `(snapshot, generation)` pairs are coherent.
    current: Mutex<(Arc<ArtifactStore>, u64)>,
    /// Serializes entire publish/remove/rescan operations (file I/O
    /// included). Readers never take it.
    mutate: Mutex<()>,
    /// Remaining ingest slots (see [`Registry::try_begin_ingest`]).
    ingest_slots: AtomicUsize,
    /// Total ingest slots.
    max_ingests: usize,
}

impl Registry {
    /// Wrap an existing store read-only: [`Registry::snapshot`] serves
    /// it forever, every mutation returns a config error (the HTTP layer
    /// maps that to 503). Used by [`super::serve`]/[`super::serve_with`].
    pub fn read_only(store: Arc<ArtifactStore>) -> Registry {
        crate::obs::REGISTRY_GENERATION.set(0);
        crate::obs::REGISTRY_ARTIFACTS.set(store.artifacts().len() as u64);
        Registry {
            dir: None,
            opts: StoreOptions::default(),
            current: Mutex::new((store, 0)),
            mutate: Mutex::new(()),
            ingest_slots: AtomicUsize::new(0),
            max_ingests: 0,
        }
    }

    /// Open every `*.sz3c` under `dir` into a **writable** registry. An
    /// empty directory is a valid (empty) serving set — unlike
    /// [`ArtifactStore::open_dir`], a write-path server legitimately
    /// starts with nothing and fills up over PUTs.
    pub fn open_dir(dir: impl AsRef<Path>, opts: &StoreOptions) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let mut store = ArtifactStore::new(opts.cache_bytes);
        for (id, path) in scan_dir(&dir)? {
            let (reader, file_bytes) = open_verified(&id, &path, opts)?;
            store.register(id, reader, file_bytes)?;
        }
        crate::obs::REGISTRY_GENERATION.set(0);
        crate::obs::REGISTRY_ARTIFACTS.set(store.artifacts().len() as u64);
        Ok(Registry {
            dir: Some(dir),
            opts: opts.clone(),
            current: Mutex::new((Arc::new(store), 0)),
            mutate: Mutex::new(()),
            ingest_slots: AtomicUsize::new(DEFAULT_MAX_INGESTS),
            max_ingests: DEFAULT_MAX_INGESTS,
        })
    }

    /// Builder-style cap on concurrent ingests (clamped to ≥ 1; default
    /// 2). Slots beyond the cap answer 429 + `Retry-After`.
    pub fn with_max_inflight_ingests(mut self, n: usize) -> Registry {
        let n = n.max(1);
        self.max_ingests = n;
        self.ingest_slots = AtomicUsize::new(n);
        self
    }

    /// Whether mutations are accepted.
    pub fn writable(&self) -> bool {
        self.dir.is_some()
    }

    /// The serving directory (writable registries only).
    pub fn artifact_dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// How this registry opens artifacts (workers, verify, cache).
    pub fn store_options(&self) -> &StoreOptions {
        &self.opts
    }

    /// Total ingest slots (0 on read-only registries).
    pub fn max_inflight_ingests(&self) -> usize {
        self.max_ingests
    }

    /// The current store epoch. Cheap (`Arc` clone under a short lock);
    /// callers keep reading from the returned store unaffected by any
    /// concurrent swap.
    pub fn snapshot(&self) -> Arc<ArtifactStore> {
        Arc::clone(&self.current_lock().0)
    }

    /// The current generation — bumped by every successful publish,
    /// remove, and set-changing rescan.
    pub fn generation(&self) -> u64 {
        self.current_lock().1
    }

    /// `(snapshot, generation)` as one coherent pair.
    pub fn snapshot_with_generation(&self) -> (Arc<ArtifactStore>, u64) {
        let cur = self.current_lock();
        (Arc::clone(&cur.0), cur.1)
    }

    /// Claim an ingest slot, or `None` when all slots are busy (the
    /// HTTP layer answers 429 + `Retry-After`). The slot frees when the
    /// returned permit drops. Tests can hold permits directly to force
    /// the back-pressure path deterministically.
    pub fn try_begin_ingest(&self) -> Option<IngestPermit<'_>> {
        let mut cur = self.ingest_slots.load(Ordering::Relaxed);
        loop {
            if cur == 0 {
                return None;
            }
            match self.ingest_slots.compare_exchange(
                cur,
                cur - 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(IngestPermit { registry: self }),
                Err(seen) => cur = seen,
            }
        }
    }

    /// Stage `container` durably as `{id}.sz3c` and publish it in one
    /// epoch swap (see the module doc for the crash-safety protocol).
    /// Returns `true` when an existing artifact was replaced. In-flight
    /// readers of a replaced artifact finish on their old snapshot; its
    /// cache scope is evicted once the swap is visible.
    pub fn publish(&self, id: &str, container: &[u8]) -> Result<bool> {
        let _mutate = self.mutate_lock();
        let Some(dir) = self.dir.as_deref() else {
            return Err(SzError::config("registry is read-only"));
        };
        let staged = dir.join(format!(
            ".{id}.ingest-{}-{}",
            std::process::id(),
            TEMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let guard = TempGuard { path: staged.clone(), armed: true };
        {
            let mut f = std::fs::File::create(&staged)?;
            f.write_all(container)?;
            f.sync_all()?;
        }
        // open + verify from the staged path before anything becomes
        // visible; the fd survives the rename below
        let (reader, file_bytes) = open_verified(id, &staged, &self.opts)?;
        let cache = Arc::clone(self.snapshot().cache());
        let artifact =
            Arc::new(Artifact::build(id.to_string(), reader, file_bytes, &cache)?);
        std::fs::rename(&staged, dir.join(format!("{id}.sz3c")))?;
        guard.disarm();
        fsync_dir(dir);
        let displaced = {
            let mut cur = self.current_lock();
            let (next, displaced) = cur.0.with_artifact(artifact);
            cur.0 = Arc::new(next);
            cur.1 += 1;
            crate::obs::REGISTRY_GENERATION.set(cur.1);
            crate::obs::REGISTRY_ARTIFACTS.set(cur.0.artifacts().len() as u64);
            displaced
        };
        match displaced {
            Some(old) => {
                cache.evict_scope(&old.scope);
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Unpublish `id`: delete its file, swap it out of the serving set,
    /// and evict its cache scope. Returns `false` (generation untouched)
    /// when `id` is not resident. In-flight readers finish on their
    /// snapshot — the artifact's reader stays open until the last `Arc`
    /// drops.
    pub fn remove(&self, id: &str) -> Result<bool> {
        let _mutate = self.mutate_lock();
        let Some(dir) = self.dir.as_deref() else {
            return Err(SzError::config("registry is read-only"));
        };
        if self.snapshot().get(id).is_none() {
            return Ok(false);
        }
        match std::fs::remove_file(dir.join(format!("{id}.sz3c"))) {
            Ok(()) => {}
            // already gone out of band: still drop it from the set
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(e.into()),
        }
        fsync_dir(dir);
        let removed = {
            let mut cur = self.current_lock();
            let (next, removed) = cur.0.without_artifact(id);
            cur.0 = Arc::new(next);
            cur.1 += 1;
            crate::obs::REGISTRY_GENERATION.set(cur.1);
            crate::obs::REGISTRY_ARTIFACTS.set(cur.0.artifacts().len() as u64);
            removed
        };
        if let Some(old) = removed {
            self.snapshot().cache().evict_scope(&old.scope);
            crate::obs::ARTIFACTS_DELETED.inc();
        }
        Ok(true)
    }

    /// Reconcile the serving set with the directory: open `*.sz3c` files
    /// that appeared out of band, drop artifacts whose files vanished,
    /// and keep everything else untouched (readers, cache scopes, and
    /// stats baselines survive a rescan). Files that fail to open or
    /// verify are skipped — a half-written foreign file must not take
    /// down the serving set; staged `.{id}.ingest-*` temp files are
    /// invisible here by their non-`.sz3c` extension. Returns
    /// `(added, dropped, kept)`; the generation bumps only if the set
    /// changed.
    pub fn rescan(&self) -> Result<(usize, usize, usize)> {
        let _mutate = self.mutate_lock();
        let Some(dir) = self.dir.as_deref() else {
            return Err(SzError::config("registry is read-only"));
        };
        let on_disk = scan_dir(dir)?;
        let disk_ids: std::collections::HashSet<&str> =
            on_disk.iter().map(|(id, _)| id.as_str()).collect();
        let base = self.snapshot();
        let mut store = Arc::clone(&base);
        let mut retired: Vec<Arc<Artifact>> = Vec::new();
        let resident: Vec<String> =
            store.artifacts().iter().map(|a| a.id.clone()).collect();
        for id in &resident {
            if !disk_ids.contains(id.as_str()) {
                let (next, removed) = store.without_artifact(id);
                store = Arc::new(next);
                if let Some(old) = removed {
                    retired.push(old);
                }
            }
        }
        let mut added = 0usize;
        for (id, path) in &on_disk {
            if store.get(id).is_some() {
                continue;
            }
            let Ok((reader, file_bytes)) = open_verified(id, path, &self.opts)
            else {
                continue;
            };
            let Ok(artifact) =
                Artifact::build(id.clone(), reader, file_bytes, store.cache())
            else {
                continue;
            };
            let (next, _) = store.with_artifact(Arc::new(artifact));
            store = Arc::new(next);
            added += 1;
        }
        let dropped = retired.len();
        let kept = store.artifacts().len() - added;
        if added > 0 || dropped > 0 {
            let mut cur = self.current_lock();
            cur.0 = store;
            cur.1 += 1;
            crate::obs::REGISTRY_GENERATION.set(cur.1);
            crate::obs::REGISTRY_ARTIFACTS.set(cur.0.artifacts().len() as u64);
        }
        for old in &retired {
            base.cache().evict_scope(&old.scope);
        }
        crate::obs::RESCANS.inc();
        Ok((added, dropped, kept))
    }

    fn current_lock(&self) -> MutexGuard<'_, (Arc<ArtifactStore>, u64)> {
        // a poisoned epoch lock still holds a coherent (store, gen) pair:
        // the swap is a single assignment, never a partial update
        self.current.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn mutate_lock(&self) -> MutexGuard<'_, ()> {
        self.mutate.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// RAII ingest slot from [`Registry::try_begin_ingest`]; dropping it
/// frees the slot for the next `PUT`.
pub struct IngestPermit<'a> {
    registry: &'a Registry,
}

impl Drop for IngestPermit<'_> {
    fn drop(&mut self) {
        self.registry.ingest_slots.fetch_add(1, Ordering::AcqRel);
    }
}

/// Deletes the staged temp file on drop unless disarmed — the error
/// paths of [`Registry::publish`] leave no debris behind.
struct TempGuard {
    path: PathBuf,
    armed: bool,
}

impl TempGuard {
    fn disarm(mut self) {
        self.armed = false;
    }
}

impl Drop for TempGuard {
    fn drop(&mut self) {
        if self.armed {
            // audit:allow(swallow, reason = "cleanup of a staged temp file that may already be gone; nothing actionable on failure")
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// All `(id, path)` pairs for `*.sz3c` files under `dir`, sorted by id.
/// Non-UTF-8 stems are skipped — they could never be addressed over the
/// API anyway.
fn scan_dir(dir: &Path) -> Result<Vec<(String, PathBuf)>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let Ok(entry) = entry else { continue };
        let path = entry.path();
        let is_artifact =
            path.extension().and_then(|e| e.to_str()) == Some("sz3c") && path.is_file();
        if !is_artifact {
            continue;
        }
        let Some(id) = path.file_stem().and_then(|s| s.to_str()).map(str::to_string)
        else {
            continue;
        };
        out.push((id, path));
    }
    out.sort();
    Ok(out)
}

/// Open a reader on `path` (CRC-verified per `opts.verify`), returning
/// it with the on-disk byte size.
fn open_verified(
    id: &str,
    path: &Path,
    opts: &StoreOptions,
) -> Result<(ContainerReader<'static>, u64)> {
    let file_bytes = std::fs::metadata(path)?.len();
    let reader = ContainerReader::open_path(path)?.with_workers(opts.workers);
    if opts.verify {
        reader.verify_checksums().map_err(|e| {
            SzError::corrupt(format!("artifact '{id}' failed verification: {e}"))
        })?;
    }
    Ok((reader, file_bytes))
}

/// Best-effort directory fsync so a rename/unlink is durable. Serving
/// correctness never depends on it — rescan reconciles after a crash.
fn fsync_dir(dir: &Path) {
    if let Ok(d) = std::fs::File::open(dir) {
        // audit:allow(swallow, reason = "directory fsync is durability hardening; the artifact file itself is already synced")
        let _ = d.sync_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JobConfig;
    use crate::coordinator::Coordinator;
    use crate::data::Field;
    use crate::pipeline::ErrorBound;

    fn container(tag: f32) -> Vec<u8> {
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(1e-3),
            workers: 1,
            chunk_elems: 256,
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let values: Vec<f32> = (0..512).map(|i| tag + (i as f32) * 0.01).collect();
        let field = Field::f32("rho", &[8, 64], values).unwrap();
        let (bytes, _) = coord.run_to_container(vec![field]).unwrap();
        bytes
    }

    fn temp_registry(name: &str) -> (PathBuf, Registry) {
        let dir = std::env::temp_dir()
            .join(format!("sz3_registry_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let reg = Registry::open_dir(&dir, &StoreOptions::default()).unwrap();
        (dir, reg)
    }

    fn read_all(store: &ArtifactStore, id: &str) -> Vec<f32> {
        let art = store.get(id).unwrap();
        let field = art.reader.read_field("rho").unwrap();
        match field.values {
            crate::data::FieldValues::F32(v) => v,
            other => panic!("unexpected dtype {other:?}"),
        }
    }

    #[test]
    fn publish_replace_remove_lifecycle() {
        let (dir, reg) = temp_registry("lifecycle");
        assert_eq!(reg.generation(), 0);
        assert!(reg.snapshot().artifacts().is_empty(), "empty dir is servable");

        assert!(!reg.publish("a", &container(1.0)).unwrap(), "fresh id: created");
        assert_eq!(reg.generation(), 1);
        assert!(dir.join("a.sz3c").exists());
        let old_snap = reg.snapshot();
        let old_values = read_all(&old_snap, "a");

        // replace: in-flight readers of old_snap stay bit-identical
        assert!(reg.publish("a", &container(100.0)).unwrap(), "same id: replaced");
        assert_eq!(reg.generation(), 2);
        assert_eq!(read_all(&old_snap, "a"), old_values, "old epoch unchanged");
        let new_values = read_all(&reg.snapshot(), "a");
        assert_ne!(new_values, old_values, "new epoch serves new bytes");

        // the two registrations never share cache scope
        let (a_old, a_new) =
            (old_snap.get("a").unwrap(), reg.snapshot());
        assert_ne!(a_old.scope, a_new.get("a").unwrap().scope);

        assert!(reg.remove("a").unwrap());
        assert_eq!(reg.generation(), 3);
        assert!(!dir.join("a.sz3c").exists());
        assert!(reg.snapshot().get("a").is_none());
        assert!(!reg.remove("a").unwrap(), "double delete is a clean false");
        assert_eq!(reg.generation(), 3, "no-op remove does not bump the epoch");

        // no staged debris anywhere in the directory
        let leftovers: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert!(leftovers.is_empty(), "no temp debris: {leftovers:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rescan_reconciles_with_directory() {
        let (dir, reg) = temp_registry("rescan");
        reg.publish("x", &container(1.0)).unwrap();
        let x_scope = reg.snapshot().get("x").unwrap().scope.clone();
        let gen_before = reg.generation();

        // a foreign artifact, a staged-style temp file, and garbage
        std::fs::copy(dir.join("x.sz3c"), dir.join("y.sz3c")).unwrap();
        std::fs::write(dir.join(".z.ingest-99-1"), b"partial upload").unwrap();
        std::fs::write(dir.join("junk.sz3c"), b"not a container").unwrap();

        let (added, dropped, kept) = reg.rescan().unwrap();
        assert_eq!((added, dropped, kept), (1, 0, 1), "y added, junk skipped");
        assert_eq!(reg.generation(), gen_before + 1);
        assert!(reg.snapshot().get("y").is_some());
        assert!(reg.snapshot().get("junk").is_none());
        assert_eq!(
            reg.snapshot().get("x").unwrap().scope,
            x_scope,
            "kept artifacts keep their registration (and cache scope)"
        );

        // vanish y's file out of band: rescan drops it
        std::fs::remove_file(dir.join("y.sz3c")).unwrap();
        let (added, dropped, _) = reg.rescan().unwrap();
        assert_eq!((added, dropped), (0, 1));
        assert!(reg.snapshot().get("y").is_none());

        // a no-change rescan leaves the generation alone
        let gen = reg.generation();
        assert_eq!(reg.rescan().unwrap(), (0, 0, 1));
        assert_eq!(reg.generation(), gen);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn publish_failure_leaves_no_debris_and_no_epoch() {
        let (dir, reg) = temp_registry("failure");
        let gen = reg.generation();
        assert!(reg.publish("bad", b"definitely not a container").is_err());
        assert_eq!(reg.generation(), gen, "failed publish must not bump");
        assert!(reg.snapshot().get("bad").is_none());
        let leftovers: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert!(leftovers.is_empty(), "staged file cleaned up: {leftovers:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn read_only_registry_rejects_mutations() {
        let store = Arc::new(ArtifactStore::new(0));
        let reg = Registry::read_only(Arc::clone(&store));
        assert!(!reg.writable());
        assert!(reg.try_begin_ingest().is_none(), "no ingest slots");
        assert!(reg.publish("a", b"x").is_err());
        assert!(reg.remove("a").is_err());
        assert!(reg.rescan().is_err());
        assert_eq!(reg.generation(), 0);
    }

    #[test]
    fn ingest_permits_are_bounded_and_raii() {
        let (dir, reg) = temp_registry("permits");
        let reg = reg.with_max_inflight_ingests(2);
        let p1 = reg.try_begin_ingest().unwrap();
        let _p2 = reg.try_begin_ingest().unwrap();
        assert!(reg.try_begin_ingest().is_none(), "slots exhausted");
        drop(p1);
        assert!(reg.try_begin_ingest().is_some(), "slot returns on drop");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
