//! Per-endpoint latency accounting for `/statsz`: lock-free atomic
//! counters plus a power-of-two-bucket histogram per endpoint, from which
//! p50/p99 are estimated. Buckets are log₂-spaced in microseconds (bucket
//! *i* covers `[2^i, 2^(i+1))` µs), so the histogram is 26 fixed `u64`s
//! per endpoint — no allocation, no mutex, safe to hammer from every
//! worker thread. Quantiles report a bucket's upper bound, i.e. they are
//! conservative to within 2×, which is plenty to see a cold/warm split or
//! a tail blowing up.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Histogram bucket count: bucket 25 tops out at ~67 s, far beyond any
/// sane request.
const N_BUCKETS: usize = 26;

/// Latency accumulator for one endpoint.
#[derive(Default)]
pub struct LatencyStats {
    count: AtomicU64,
    total_us: AtomicU64,
    max_us: AtomicU64,
    buckets: [AtomicU64; N_BUCKETS],
}

/// Point-in-time summary of one endpoint's latency distribution.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LatencySummary {
    /// Requests recorded.
    pub count: u64,
    /// Mean latency in microseconds.
    pub mean_us: u64,
    /// Estimated median (upper bucket bound), microseconds.
    pub p50_us: u64,
    /// Estimated 99th percentile (upper bucket bound), microseconds.
    pub p99_us: u64,
    /// Slowest request observed, microseconds.
    pub max_us: u64,
}

impl LatencyStats {
    /// Record one request's latency.
    pub fn record(&self, elapsed: Duration) {
        let us = elapsed.as_micros().min(u64::MAX as u128) as u64;
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
        let idx = if us <= 1 {
            0
        } else {
            ((63 - us.leading_zeros()) as usize).min(N_BUCKETS - 1)
        };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Upper bound (µs) of the bucket containing quantile `q` (0..=1).
    fn quantile_us(&self, q: f64, counts: &[u64; N_BUCKETS], total: u64) -> u64 {
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us.load(Ordering::Relaxed)
    }

    /// Snapshot the distribution. Counters advance concurrently, so the
    /// summary is approximate during traffic — fine for observability.
    pub fn summary(&self) -> LatencySummary {
        let count = self.count.load(Ordering::Relaxed);
        let total = self.total_us.load(Ordering::Relaxed);
        let mut counts = [0u64; N_BUCKETS];
        for (slot, b) in counts.iter_mut().zip(self.buckets.iter()) {
            *slot = b.load(Ordering::Relaxed);
        }
        let histo_total: u64 = counts.iter().sum();
        LatencySummary {
            count,
            mean_us: if count == 0 { 0 } else { total / count },
            p50_us: self.quantile_us(0.50, &counts, histo_total),
            p99_us: self.quantile_us(0.99, &counts, histo_total),
            max_us: self.max_us.load(Ordering::Relaxed),
        }
    }
}

/// Endpoint labels tracked by [`ServerStats`] — one slot per API surface
/// plus a catch-all for unmatched routes.
pub const ENDPOINTS: [&str; 7] =
    ["list", "meta", "roi", "raw", "healthz", "statsz", "other"];

/// All endpoint latency slots plus the server start instant.
pub struct ServerStats {
    slots: Vec<LatencyStats>,
    started: std::time::Instant,
}

impl Default for ServerStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerStats {
    /// Fresh stats, uptime starting now.
    pub fn new() -> ServerStats {
        ServerStats {
            slots: ENDPOINTS.iter().map(|_| LatencyStats::default()).collect(),
            started: std::time::Instant::now(),
        }
    }

    /// Record a request against `label` (unknown labels fold into
    /// `"other"`).
    pub fn record(&self, label: &str, elapsed: Duration) {
        let idx = ENDPOINTS
            .iter()
            .position(|&e| e == label)
            .unwrap_or(ENDPOINTS.len() - 1);
        self.slots[idx].record(elapsed);
    }

    /// Summary for one endpoint label.
    pub fn summary(&self, label: &str) -> LatencySummary {
        let idx = ENDPOINTS
            .iter()
            .position(|&e| e == label)
            .unwrap_or(ENDPOINTS.len() - 1);
        self.slots[idx].summary()
    }

    /// (label, summary) for every endpoint, in [`ENDPOINTS`] order.
    pub fn summaries(&self) -> Vec<(&'static str, LatencySummary)> {
        ENDPOINTS
            .iter()
            .zip(self.slots.iter())
            .map(|(&label, s)| (label, s.summary()))
            .collect()
    }

    /// Seconds since the stats (≈ the server) started.
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes_quantiles() {
        let s = LatencyStats::default();
        // 99 fast requests (~100µs) and one slow outlier (~50ms)
        for _ in 0..99 {
            s.record(Duration::from_micros(100));
        }
        s.record(Duration::from_millis(50));
        let sum = s.summary();
        assert_eq!(sum.count, 100);
        assert_eq!(sum.max_us, 50_000);
        // 100µs lands in bucket [64,128) → p50 reports 128
        assert_eq!(sum.p50_us, 128);
        assert!(
            sum.p99_us <= 256,
            "p99 still inside the fast band at 99/100: {}",
            sum.p99_us
        );
        assert!(sum.mean_us >= 100 && sum.mean_us < 1000);
        // the outlier is visible one step further out
        assert!(s.quantile_us(1.0, &snapshot(&s), 100) >= 50_000 || sum.max_us >= 50_000);
    }

    fn snapshot(s: &LatencyStats) -> [u64; N_BUCKETS] {
        let mut counts = [0u64; N_BUCKETS];
        for (slot, b) in counts.iter_mut().zip(s.buckets.iter()) {
            *slot = b.load(Ordering::Relaxed);
        }
        counts
    }

    #[test]
    fn empty_stats_are_all_zero() {
        let s = LatencyStats::default();
        assert_eq!(s.summary(), LatencySummary::default());
    }

    #[test]
    fn server_stats_routes_labels() {
        let s = ServerStats::new();
        s.record("roi", Duration::from_micros(300));
        s.record("nonsense", Duration::from_micros(10));
        assert_eq!(s.summary("roi").count, 1);
        assert_eq!(s.summary("other").count, 1);
        assert_eq!(s.summary("raw").count, 0);
        assert_eq!(s.summaries().len(), ENDPOINTS.len());
    }
}
