//! Per-endpoint latency accounting for `/statsz`, backed by the
//! process-wide observability primitives in [`crate::obs`]: each endpoint
//! owns a lock-free log₂-bucket [`obs::Histogram`] (bucket *i* covers
//! `[2^i, 2^(i+1))` µs — 26 fixed `u64`s, no allocation, no mutex, safe
//! to hammer from every worker thread). Quantiles **interpolate linearly
//! within the winning bucket** (see [`obs::HistSnapshot::quantile_us`]),
//! so p50/p99 are exact for uniform in-bucket distributions instead of
//! the former conservative-to-2× upper-bound estimate. The bucket
//! boundaries themselves are reported in the `/statsz` JSON
//! (`latency_buckets_us`) so clients can reconstruct the histogram's
//! resolution.

use crate::obs::{self, HistSnapshot, Histogram};
use std::time::Duration;

/// Histogram bucket count (re-exported from [`obs::N_BUCKETS`]).
pub const N_BUCKETS: usize = obs::N_BUCKETS;

/// Latency accumulator for one endpoint.
#[derive(Default)]
pub struct LatencyStats {
    hist: Histogram,
}

/// Point-in-time summary of one endpoint's latency distribution.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LatencySummary {
    /// Requests recorded.
    pub count: u64,
    /// Mean latency in microseconds.
    pub mean_us: u64,
    /// Estimated median, microseconds (interpolated within its bucket).
    pub p50_us: u64,
    /// Estimated 99th percentile, microseconds (interpolated).
    pub p99_us: u64,
    /// Slowest request observed, microseconds.
    pub max_us: u64,
}

impl LatencyStats {
    /// Record one request's latency.
    pub fn record(&self, elapsed: Duration) {
        self.hist.observe(elapsed);
    }

    /// Copy of the underlying distribution.
    pub fn snapshot(&self) -> HistSnapshot {
        self.hist.snapshot()
    }

    /// Snapshot the distribution. Counters advance concurrently, so the
    /// summary is approximate during traffic — fine for observability.
    pub fn summary(&self) -> LatencySummary {
        let s = self.hist.snapshot();
        LatencySummary {
            count: s.n,
            mean_us: s.mean_us(),
            p50_us: s.quantile_us(0.50),
            p99_us: s.quantile_us(0.99),
            max_us: s.max_us,
        }
    }
}

/// Endpoint labels tracked by [`ServerStats`] — one slot per API surface
/// plus a catch-all for unmatched routes. Shared with the Prometheus
/// exposition layer so `/statsz` and `/metricsz` agree on the vocabulary.
pub const ENDPOINTS: [&str; 11] = obs::HTTP_ENDPOINTS;

/// Upper bounds (µs, exclusive) of the latency histogram buckets, for the
/// `/statsz` JSON's `latency_buckets_us` field.
pub fn bucket_bounds_us() -> [u64; N_BUCKETS] {
    let mut out = [0u64; N_BUCKETS];
    for (slot, b) in out.iter_mut().enumerate() {
        *b = obs::bucket_hi_us(slot);
    }
    out
}

/// All endpoint latency slots plus the server start instant.
pub struct ServerStats {
    slots: Vec<LatencyStats>,
    started: std::time::Instant,
}

impl Default for ServerStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerStats {
    /// Fresh stats, uptime starting now.
    pub fn new() -> ServerStats {
        ServerStats {
            slots: ENDPOINTS.iter().map(|_| LatencyStats::default()).collect(),
            started: std::time::Instant::now(),
        }
    }

    /// Record a request against `label` (unknown labels fold into
    /// `"other"`).
    pub fn record(&self, label: &str, elapsed: Duration) {
        let idx = ENDPOINTS
            .iter()
            .position(|&e| e == label)
            .unwrap_or(ENDPOINTS.len() - 1);
        if let Some(slot) = self.slots.get(idx) {
            slot.record(elapsed);
        }
    }

    /// Summary for one endpoint label.
    pub fn summary(&self, label: &str) -> LatencySummary {
        let idx = ENDPOINTS
            .iter()
            .position(|&e| e == label)
            .unwrap_or(ENDPOINTS.len() - 1);
        self.slots.get(idx).map(|s| s.summary()).unwrap_or_default()
    }

    /// (label, summary) for every endpoint, in [`ENDPOINTS`] order.
    pub fn summaries(&self) -> Vec<(&'static str, LatencySummary)> {
        ENDPOINTS
            .iter()
            .zip(self.slots.iter())
            .map(|(&label, s)| (label, s.summary()))
            .collect()
    }

    /// Seconds since the stats (≈ the server) started.
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes_quantiles() {
        let s = LatencyStats::default();
        // 99 fast requests (~100µs) and one slow outlier (~50ms)
        for _ in 0..99 {
            s.record(Duration::from_micros(100));
        }
        s.record(Duration::from_millis(50));
        let sum = s.summary();
        assert_eq!(sum.count, 100);
        assert_eq!(sum.max_us, 50_000);
        // 100µs lands in bucket [64,128); interpolation keeps p50 strictly
        // inside that bucket instead of pinning it to the 128 upper bound
        assert!(
            sum.p50_us >= 64 && sum.p50_us < 128,
            "p50 must interpolate within [64,128): {}",
            sum.p50_us
        );
        assert!(
            sum.p99_us < 256,
            "p99 still inside the fast band at 99/100: {}",
            sum.p99_us
        );
        assert!(sum.mean_us >= 100 && sum.mean_us < 1000);
        // the outlier dominates the extreme tail
        assert!(s.snapshot().quantile_us(1.0) >= 32_768);
    }

    #[test]
    fn empty_stats_are_all_zero() {
        let s = LatencyStats::default();
        assert_eq!(s.summary(), LatencySummary::default());
    }

    #[test]
    fn bucket_bounds_are_log2_spaced() {
        let bounds = bucket_bounds_us();
        assert_eq!(bounds[0], 2);
        for w in bounds.windows(2) {
            assert_eq!(w[1], w[0] * 2, "upper bounds must double");
        }
    }

    #[test]
    fn server_stats_routes_labels() {
        let s = ServerStats::new();
        s.record("roi", Duration::from_micros(300));
        s.record("nonsense", Duration::from_micros(10));
        assert_eq!(s.summary("roi").count, 1);
        assert_eq!(s.summary("other").count, 1);
        assert_eq!(s.summary("raw").count, 0);
        assert_eq!(s.summaries().len(), ENDPOINTS.len());
        assert!(ENDPOINTS.contains(&"metricsz"), "exposition endpoint tracked");
    }
}
