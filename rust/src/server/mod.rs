//! HTTP range-query serving layer over the random-access container
//! reader — the network front of the serve path.
//!
//! `sz3 serve-http` publishes a directory of `SZ3C` artifacts over a
//! dependency-free HTTP/1.1 server: a std [`std::net::TcpListener`]
//! accept loop feeding a fixed [`pool::ThreadPool`] of connection
//! workers (hyper/axum/tokio are unavailable offline, and the endpoints
//! are simple enough that a bounded hand-rolled server is the honest
//! cost). Each artifact is opened **once** through
//! [`crate::reader::ContainerReader`] and held for the server's
//! lifetime; all readers charge decoded chunks against one shared
//! byte-budgeted [`crate::reader::ChunkCache`], so a single `--cache-mb`
//! knob bounds the whole process no matter how many artifacts are
//! registered.
//!
//! # Endpoints
//!
//! | route | purpose |
//! |---|---|
//! | `GET /v1/artifacts` | list registered artifacts |
//! | `GET /v1/artifacts/{id}` | index/metadata JSON (fields, dims, chunk map) |
//! | `GET /v1/artifacts/{id}/fields/{name}?rows=A..B&snapshot=K&format=f32\|raw\|json` | ROI extraction — decodes only overlapping chunks of snapshot K (default 0) |
//! | `GET /v1/artifacts/{id}/raw?chunk=N` | compressed chunk passthrough for client-side decode |
//! | `PUT /v1/artifacts/{id}` | ingest: compress raw f32 fields and publish atomically (see below) |
//! | `DELETE /v1/artifacts/{id}` | unpublish an artifact and delete its file |
//! | `POST /v1/admin/rescan` | pick up `*.sz3c` files added to the directory out of band |
//! | `GET /healthz` | liveness |
//! | `GET /statsz` | [`crate::reader::ReadStats`] per artifact + per-endpoint latency |
//! | `GET /metricsz` | Prometheus text exposition of the process-wide [`crate::obs`] registry |
//!
//! The full API contract (query params, status codes, error body, cache
//! semantics, `curl` examples) is specified in `docs/SERVE.md`.
//!
//! # Write path
//!
//! Mutations go through a [`Registry`] — an epoch-pointer wrapper around
//! an immutable [`ArtifactStore`]: readers snapshot an `Arc` per request
//! and never block, writers build a successor store (sharing every
//! unchanged artifact) and swap the pointer under a lock. `PUT` bodies
//! are compressed through the coordinator, staged to a temp file,
//! fsynced, verified, and only then renamed to `{id}.sz3c` and published
//! — a crash at any earlier point leaves no visible debris. Back-pressure
//! is explicit: a bounded ingest-slot pool answers 429 + `Retry-After`
//! when saturated, an accept-side connection cap answers 503, and
//! [`ServeOptions::max_body`] bounds request bodies with 413. Servers
//! started via [`serve`]/[`serve_with`] wrap their store in a read-only
//! registry and answer 503 to every mutation.
//!
//! # Observability
//!
//! Every response carries an `X-Request-Id` header — echoed from the
//! request when the client sent a well-formed one (1–64 chars of
//! `[A-Za-z0-9._-]`), generated otherwise — so a client-side log line and
//! a server-side access-log line can be joined on the id. Access logs
//! (`--log-format text|json`, off by default) are one line per request on
//! stderr: id, method, route label, path, status, body bytes, and
//! handling microseconds.
//!
//! # Concurrency shape
//!
//! `--threads` HTTP workers each own at most one connection at a time
//! (keep-alive supported; idle connections close after a read timeout).
//! A region request fans out chunk decodes across the reader's own
//! worker pool, so one request can still use many cores while the HTTP
//! pool bounds how many requests execute at once. Readers are shared
//! (`&ContainerReader` across threads) — chunk fetches, CRC checks,
//! decodes, and cache probes are all `&self` operations backed by
//! atomics/mutexes, a property the concurrent-access integration test
//! pins down.

pub mod client;
pub mod handlers;
pub mod http;
pub mod pool;
pub mod registry;
pub mod stats;

pub use client::{HttpClient, HttpResponse};
pub use http::{Request, Response};
pub use registry::{IngestPermit, Registry};
pub use stats::{LatencySummary, ServerStats};

use crate::error::{Result, SzError};
use crate::pipeline;
use crate::reader::{ChunkCache, ContainerReader};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-connection read timeout: a keep-alive connection idle this long is
/// closed, which also bounds how long shutdown can wait on a worker.
const IDLE_TIMEOUT: Duration = Duration::from_secs(5);

/// Access-log output selector for [`ServeOptions`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LogFormat {
    /// No access logging (the default — tests and embedded servers stay
    /// quiet).
    None,
    /// One human-readable `key=value` line per request on stderr.
    Text,
    /// One JSON object per request on stderr (newline-delimited).
    Json,
}

/// How [`serve_with`]/[`serve_registry`] run the connection loop.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// HTTP connection workers.
    pub threads: usize,
    /// Access-log format (stderr).
    pub log: LogFormat,
    /// Largest accepted request body in bytes. A declared `Content-Length`
    /// beyond this is refused with 413 before a byte of body is read.
    pub max_body: usize,
    /// Simultaneously served (or queued) connections. Accepts beyond this
    /// get an immediate `503` + `Retry-After: 1` and are closed, so load
    /// sheds at the edge instead of queueing unboundedly.
    pub max_conns: usize,
    /// Per-connection socket read timeout: an idle keep-alive closes
    /// quietly, a peer that stalls mid-request gets `408`.
    pub read_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            threads: crate::util::default_workers(),
            log: LogFormat::None,
            max_body: 256 << 20,
            max_conns: 256,
            read_timeout: IDLE_TIMEOUT,
        }
    }
}

/// Monotonic sequence feeding generated request ids.
static REQUEST_SEQ: AtomicU64 = AtomicU64::new(1);

/// The response's `X-Request-Id`: the client's own id when it sent a
/// well-formed one (1–64 chars of `[A-Za-z0-9._-]` — anything else is
/// discarded rather than reflected into logs), a generated
/// `sz3-<pid>-<seq>` otherwise.
fn request_id(req: &Request) -> String {
    if let Some(id) = req.header("x-request-id") {
        let well_formed = !id.is_empty()
            && id.len() <= 64
            && id.bytes().all(|b| {
                b.is_ascii_alphanumeric() || b == b'-' || b == b'_' || b == b'.'
            });
        if well_formed {
            return id.to_string();
        }
    }
    let seq = REQUEST_SEQ.fetch_add(1, Ordering::Relaxed);
    // golden-ratio mix so concurrent ids don't read as a tidy sequence
    // (they are not a security token, just a join key for logs)
    format!("sz3-{:x}-{:016x}", std::process::id(), seq.wrapping_mul(0x9e37_79b9_7f4a_7c15))
}

/// How a directory of artifacts is opened into an [`ArtifactStore`].
#[derive(Clone, Debug)]
pub struct StoreOptions {
    /// Shared decoded-chunk cache budget in bytes (0 disables caching).
    pub cache_bytes: usize,
    /// Per-reader decode fan-out (chunks decoded in parallel per request).
    pub workers: usize,
    /// CRC-verify every chunk of every artifact before publishing it —
    /// the reader-era serve path's "never publish a corrupt artifact"
    /// rule, now at server startup.
    pub verify: bool,
}

impl Default for StoreOptions {
    fn default() -> Self {
        StoreOptions {
            cache_bytes: 64 << 20,
            workers: crate::util::default_workers(),
            verify: true,
        }
    }
}

/// Per-field metadata surfaced by the list/meta endpoints without
/// decoding anything at request time.
pub struct FieldInfo {
    /// Field name.
    pub name: String,
    /// Full dims, slowest axis first.
    pub dims: Vec<usize>,
    /// Element dtype tag ("f32"/"f64"/"i32"), peeked from the field's
    /// first chunk header at registration.
    pub dtype: String,
    /// Chunk count.
    pub chunks: usize,
}

/// Monotonic sequence making each registration's cache scope unique.
static SCOPE_SEQ: AtomicU64 = AtomicU64::new(1);

/// One registered artifact: id (file stem), an open reader, and metadata
/// captured at registration.
pub struct Artifact {
    /// Artifact id — the file stem, as it appears in URLs.
    pub id: String,
    /// Shared-cache scope for this registration: `{id}/{seq}` with a
    /// process-unique sequence number (ids cannot contain `/`, so scopes
    /// never collide with other ids). A replacement registration of the
    /// same id therefore never shares cache keys with its predecessor —
    /// the registry evicts a retired scope purely to reclaim budget.
    pub scope: String,
    /// The open indexed-seek reader (shared by all request threads).
    pub reader: ContainerReader<'static>,
    /// On-disk artifact size in bytes.
    pub file_bytes: u64,
    /// Per-field metadata in first-appearance order.
    pub fields: Vec<FieldInfo>,
    /// Reader counters as of registration (startup CRC sweep + dtype
    /// peeks). `/statsz` subtracts this so its numbers reflect
    /// request-driven traffic only.
    baseline: crate::reader::ReadStats,
}

impl Artifact {
    /// Turn an open reader into a servable artifact: validate that the
    /// series is rectangular, attach the shared cache under a fresh
    /// unique scope, capture per-field metadata, and snapshot the stats
    /// baseline. Used by [`ArtifactStore::register`] at startup and by
    /// the [`Registry`] when publishing live.
    pub(crate) fn build(
        id: String,
        reader: ContainerReader<'static>,
        file_bytes: u64,
        cache: &Arc<ChunkCache>,
    ) -> Result<Artifact> {
        // the serve path registers snapshot-0 field metadata once and
        // validates requests against it, so every snapshot must present
        // the same fields with the same dims (the series packer always
        // produces this; a hand-crafted ragged artifact is refused here
        // instead of surfacing as bogus 416/500s at request time)
        for snapshot in 1..reader.snapshot_count() {
            if reader.field_names_at(snapshot) != reader.field_names() {
                return Err(SzError::config(format!(
                    "artifact '{id}': snapshot {snapshot} holds fields {:?}, \
                     snapshot 0 holds {:?} — ragged series are not servable",
                    reader.field_names_at(snapshot),
                    reader.field_names()
                )));
            }
            for name in reader.field_names() {
                if reader.field_dims_at(snapshot, name)? != reader.field_dims(name)? {
                    return Err(SzError::config(format!(
                        "artifact '{id}': field '{name}' changes dims at \
                         snapshot {snapshot} — ragged series are not servable"
                    )));
                }
            }
        }
        let scope =
            format!("{id}/{}", SCOPE_SEQ.fetch_add(1, Ordering::Relaxed));
        let reader = reader.with_shared_cache(Arc::clone(cache), &scope);
        let mut fields = Vec::new();
        for name in reader.field_names().into_iter().map(str::to_string) {
            let dims = reader.field_dims(&name)?.to_vec();
            let chunks = reader.field_chunks(&name)?;
            // dtype lives only in the inner stream headers: peek the
            // field's first snapshot-0 chunk once at registration, never
            // per request (snapshot 0 is never delta-encoded)
            let first = reader
                .index()
                .entries
                .iter()
                .position(|e| e.field == name && e.chunk_index == 0 && e.snapshot == 0)
                .ok_or_else(|| {
                    SzError::corrupt(format!("field '{name}' has no chunk 0"))
                })?;
            let head = reader.chunk_payload(first)?;
            let dtype = pipeline::peek_header(&head)?.dtype;
            fields.push(FieldInfo { name, dims, dtype, chunks });
        }
        // snapshot after the verify sweep and dtype peeks so /statsz can
        // report request-driven counters only
        let baseline = reader.stats();
        Ok(Artifact { id, scope, reader, file_bytes, fields, baseline })
    }

    /// Reader counters attributable to requests (registration-time
    /// verification and header peeks subtracted out).
    pub fn request_stats(&self) -> crate::reader::ReadStats {
        let s = self.reader.stats();
        let b = self.baseline;
        crate::reader::ReadStats {
            chunks_fetched: s.chunks_fetched.saturating_sub(b.chunks_fetched),
            bytes_fetched: s.bytes_fetched.saturating_sub(b.bytes_fetched),
            crc_verified: s.crc_verified.saturating_sub(b.crc_verified),
            chunks_decoded: s.chunks_decoded.saturating_sub(b.chunks_decoded),
            cache_hits: s.cache_hits.saturating_sub(b.cache_hits),
            delta_applied: s.delta_applied.saturating_sub(b.delta_applied),
        }
    }
}

/// Every artifact the server holds open, plus the shared chunk cache they
/// all charge against. Artifacts are individually `Arc`'d so the
/// [`Registry`] can build a successor store that shares every unchanged
/// artifact instead of reopening them.
pub struct ArtifactStore {
    artifacts: Vec<Arc<Artifact>>,
    cache: Arc<ChunkCache>,
}

impl ArtifactStore {
    /// Empty store with a shared cache of `cache_bytes`.
    pub fn new(cache_bytes: usize) -> ArtifactStore {
        ArtifactStore {
            artifacts: Vec::new(),
            cache: Arc::new(ChunkCache::new(cache_bytes)),
        }
    }

    /// Open every `*.sz3c` file under `dir` (non-recursive), id'd by file
    /// stem, sorted by id. With `opts.verify`, every chunk of every
    /// artifact is CRC-checked before the store is returned — a corrupt
    /// artifact fails startup instead of surfacing as a 500 later.
    pub fn open_dir(dir: impl AsRef<Path>, opts: &StoreOptions) -> Result<ArtifactStore> {
        let dir = dir.as_ref();
        let mut store = ArtifactStore::new(opts.cache_bytes);
        let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.extension().and_then(|e| e.to_str()) == Some("sz3c")
                    && p.is_file()
            })
            .collect();
        paths.sort();
        if paths.is_empty() {
            return Err(SzError::config(format!(
                "no .sz3c artifacts under {}",
                dir.display()
            )));
        }
        for path in paths {
            let id = path
                .file_stem()
                .and_then(|s| s.to_str())
                .ok_or_else(|| {
                    SzError::config(format!("unusable artifact name {}", path.display()))
                })?
                .to_string();
            let file_bytes = std::fs::metadata(&path)?.len();
            let reader = ContainerReader::open_path(&path)?.with_workers(opts.workers);
            if opts.verify {
                reader.verify_checksums().map_err(|e| {
                    SzError::corrupt(format!("artifact '{id}' failed verification: {e}"))
                })?;
            }
            store.register(id, reader, file_bytes)?;
        }
        Ok(store)
    }

    /// Register an already-open reader under `id`, attaching it to the
    /// shared cache under a fresh scope. Duplicate ids are rejected.
    pub fn register(
        &mut self,
        id: String,
        reader: ContainerReader<'static>,
        file_bytes: u64,
    ) -> Result<()> {
        if self.get(&id).is_some() {
            return Err(SzError::config(format!("duplicate artifact id '{id}'")));
        }
        let artifact = Artifact::build(id, reader, file_bytes, &self.cache)?;
        self.artifacts.push(Arc::new(artifact));
        self.artifacts.sort_by(|a, b| a.id.cmp(&b.id));
        Ok(())
    }

    /// Look up an artifact by id.
    pub fn get(&self, id: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.id == id).map(|a| a.as_ref())
    }

    /// All artifacts, sorted by id.
    pub fn artifacts(&self) -> &[Arc<Artifact>] {
        &self.artifacts
    }

    /// The shared decoded-chunk cache.
    pub fn cache(&self) -> &Arc<ChunkCache> {
        &self.cache
    }

    /// A successor store sharing this store's cache and every unchanged
    /// artifact, with `artifact` added (replacing any same-id resident).
    /// Returns the displaced artifact, if any, so the caller can retire
    /// its cache scope.
    pub(crate) fn with_artifact(
        &self,
        artifact: Arc<Artifact>,
    ) -> (ArtifactStore, Option<Arc<Artifact>>) {
        let mut artifacts: Vec<Arc<Artifact>> =
            Vec::with_capacity(self.artifacts.len() + 1);
        let mut displaced = None;
        for a in &self.artifacts {
            if a.id == artifact.id {
                displaced = Some(Arc::clone(a));
            } else {
                artifacts.push(Arc::clone(a));
            }
        }
        artifacts.push(artifact);
        artifacts.sort_by(|a, b| a.id.cmp(&b.id));
        (ArtifactStore { artifacts, cache: Arc::clone(&self.cache) }, displaced)
    }

    /// A successor store without the artifact named `id` (shares the
    /// cache and every surviving artifact). Returns the removed artifact,
    /// or `None` if `id` was not resident.
    pub(crate) fn without_artifact(
        &self,
        id: &str,
    ) -> (ArtifactStore, Option<Arc<Artifact>>) {
        let mut artifacts: Vec<Arc<Artifact>> =
            Vec::with_capacity(self.artifacts.len());
        let mut removed = None;
        for a in &self.artifacts {
            if a.id == id {
                removed = Some(Arc::clone(a));
            } else {
                artifacts.push(Arc::clone(a));
            }
        }
        (ArtifactStore { artifacts, cache: Arc::clone(&self.cache) }, removed)
    }
}

/// Handle to a running server: address, live stats/registry access, and
/// deterministic shutdown.
pub struct ServerHandle {
    addr: SocketAddr,
    registry: Arc<Registry>,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point-in-time snapshot of the artifact store the server answers
    /// from (the current registry epoch; a concurrent PUT/DELETE makes
    /// the snapshot stale, not wrong).
    pub fn store(&self) -> Arc<ArtifactStore> {
        self.registry.snapshot()
    }

    /// The registry behind the server — mutation entry points and the
    /// ingest-permit pool live here.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Live latency/endpoint stats.
    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Stop accepting, drain queued connections, join every worker.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    /// Block until the accept loop exits (it doesn't, short of `shutdown`
    /// from another thread or process death) — the CLI's foreground mode.
    pub fn run_forever(mut self) {
        if let Some(h) = self.accept.take() {
            // audit:allow(swallow, reason = "a panicked accept loop still means the server is done; nothing to report to")
            let _ = h.join();
        }
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop with a throwaway connection
        // audit:allow(swallow, reason = "the connection exists only to wake the accept loop; refusal means it already exited")
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            // audit:allow(swallow, reason = "shutdown path; a panicked accept thread is already stopped")
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_and_join();
        }
    }
}

/// Bind `addr` (e.g. `127.0.0.1:8080`, port 0 for ephemeral) and serve
/// `store` **read-only** on `threads` connection workers until the
/// returned handle is shut down. Access logging is off; use
/// [`serve_with`] to enable it, [`serve_registry`] for the write path.
pub fn serve(store: ArtifactStore, addr: &str, threads: usize) -> Result<ServerHandle> {
    serve_with(store, addr, ServeOptions { threads, ..ServeOptions::default() })
}

/// [`serve`] with full [`ServeOptions`] control. The store is wrapped in
/// a read-only [`Registry`]: `PUT`/`DELETE`/rescan answer 503.
pub fn serve_with(
    store: ArtifactStore,
    addr: &str,
    opts: ServeOptions,
) -> Result<ServerHandle> {
    serve_registry(Arc::new(Registry::read_only(Arc::new(store))), addr, opts)
}

/// Serve a [`Registry`] — the full read+write API when the registry is
/// writable. The caller keeps its own `Arc` to drive mutations or pin
/// ingest permits out-of-band (tests use that for deterministic 429s).
pub fn serve_registry(
    registry: Arc<Registry>,
    addr: &str,
    opts: ServeOptions,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| SzError::config(format!("binding {addr}: {e}")))?;
    let local = listener.local_addr()?;
    let stats = Arc::new(ServerStats::new());
    let stop = Arc::new(AtomicBool::new(false));
    let threads = opts.threads;
    let max_conns = opts.max_conns.max(1);
    let accept = {
        let registry = Arc::clone(&registry);
        let stats = Arc::clone(&stats);
        let stop = Arc::clone(&stop);
        std::thread::Builder::new()
            .name("sz3-http-accept".to_string())
            .spawn(move || {
                let pool = pool::ThreadPool::new(threads);
                // connections handed to the pool but not yet finished;
                // bounds the accept queue so overload sheds as 503 at
                // the edge instead of growing an invisible backlog
                let live = Arc::new(AtomicUsize::new(0));
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let mut stream = match conn {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    if live.load(Ordering::SeqCst) >= max_conns {
                        let resp = Response::error(
                            503,
                            "connection limit reached; retry shortly",
                        )
                        .with_header("Retry-After", "1");
                        // audit:allow(swallow, reason = "best-effort shed response; the connection is being dropped either way")
                        let _ = resp.write_to(&mut stream, true, false);
                        continue;
                    }
                    live.fetch_add(1, Ordering::SeqCst);
                    let registry = Arc::clone(&registry);
                    let stats = Arc::clone(&stats);
                    let stop = Arc::clone(&stop);
                    let live = Arc::clone(&live);
                    pool.execute(move || {
                        handle_connection(stream, &registry, &stats, &stop, opts);
                        live.fetch_sub(1, Ordering::SeqCst);
                    });
                }
                // pool drops here: queued connections drain, workers join
            })
            .map_err(|e| SzError::config(format!("spawning accept thread: {e}")))?
    };
    Ok(ServerHandle { addr: local, registry, stats, stop, accept: Some(accept) })
}

/// Emit one access-log line for a completed request.
fn access_log(
    log: LogFormat,
    id: &str,
    method: &str,
    label: &str,
    path: &str,
    status: u16,
    bytes: usize,
    us: u128,
) {
    match log {
        LogFormat::None => {}
        LogFormat::Text => eprintln!(
            "[access] id={id} method={method} route={label} path={path} \
             status={status} bytes={bytes} us={us}"
        ),
        LogFormat::Json => eprintln!(
            "{{\"id\":\"{}\",\"method\":\"{}\",\"route\":\"{}\",\"path\":\"{}\",\
             \"status\":{},\"bytes\":{},\"us\":{}}}",
            http::json_escape(id),
            http::json_escape(method),
            http::json_escape(label),
            http::json_escape(path),
            status,
            bytes,
            us
        ),
    }
}

/// Serve one connection: keep-alive request loop with an idle timeout,
/// closing on classified read errors (413 for an oversized body, 408 for
/// a mid-request stall, 400 for garbage, quietly on disconnect) or
/// `Connection: close`. Every response is stamped with an `X-Request-Id`
/// before it leaves.
fn handle_connection(
    stream: TcpStream,
    registry: &Registry,
    stats: &ServerStats,
    stop: &AtomicBool,
    opts: ServeOptions,
) {
    let timeout =
        if opts.read_timeout.is_zero() { IDLE_TIMEOUT } else { opts.read_timeout };
    // audit:allow(swallow, reason = "a socket without timeouts still serves; the idle cap is best-effort")
    let _ = stream.set_read_timeout(Some(timeout));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let log = opts.log;
    let mut reader = BufReader::new(stream);
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let req = match http::read_request_limited(&mut reader, opts.max_body) {
            Ok(Some(r)) => r,
            Ok(None) => break, // clean EOF or idle timeout between requests
            Err(http::ReadError::TooLarge(msg)) => {
                let resp = Response::error(413, &msg);
                // audit:allow(swallow, reason = "best-effort refusal to an over-limit peer; the connection closes either way")
                let _ = resp.write_to(&mut writer, true, false);
                break;
            }
            Err(http::ReadError::Timeout) => {
                let resp =
                    Response::error(408, "timed out reading the request");
                // audit:allow(swallow, reason = "best-effort 408 to a stalled peer; the connection closes either way")
                let _ = resp.write_to(&mut writer, true, false);
                break;
            }
            Err(http::ReadError::Malformed(msg)) => {
                let resp = Response::error(400, &msg);
                // audit:allow(swallow, reason = "best-effort 400 to a peer that already sent garbage; the connection closes either way")
                let _ = resp.write_to(&mut writer, true, false);
                break;
            }
            Err(http::ReadError::Disconnect) => break,
        };
        let close = req.close;
        let head_only = req.method == "HEAD";
        let rid = request_id(&req);
        let t0 = Instant::now();
        let (label, resp) = handlers::dispatch_labeled(registry, stats, &req);
        let resp = resp.with_header("X-Request-Id", rid.clone());
        let write_ok = resp.write_to(&mut writer, close, head_only).is_ok();
        access_log(
            log,
            &rid,
            &req.method,
            label,
            &req.path,
            resp.status,
            resp.body.len(),
            t0.elapsed().as_micros(),
        );
        if !write_ok || close {
            break;
        }
    }
}
