//! Minimal HTTP/1.1 substrate (hyper/axum are unavailable offline): a
//! bounded request parser over any `BufRead`, and a response type that
//! writes status line, headers, `Content-Length`, and body.
//!
//! Scope is deliberately narrow — exactly what the artifact-serving
//! endpoints need: `GET`/`HEAD` reads plus `PUT`/`DELETE`/`POST` on the
//! write path, bodies framed by `Content-Length` only (no chunked
//! transfer), percent-decoding for paths and query strings, keep-alive by
//! default with `Connection: close` honored. Limits (request-line and
//! header sizes, header count, body size) are enforced before any
//! allocation is sized from untrusted input, mirroring how the container
//! index parser treats its bytes. [`read_request_limited`] classifies
//! read failures (too large / timed out / malformed / peer vanished) so
//! the connection loop can answer 413/408/400 or close quietly.

use crate::error::{Result, SzError};
use std::io::{BufRead, Read, Write};

/// Request line length cap (bytes, CRLF included).
const MAX_LINE: usize = 8192;
/// Maximum number of headers accepted.
const MAX_HEADERS: usize = 64;
/// Default body cap for callers that don't configure one (read-only
/// endpoints: small strays are drained to keep the connection framed,
/// anything larger is rejected outright).
const MAX_DRAIN_BODY: usize = 1 << 20;

/// Why a limits-aware request read failed — the connection loop maps
/// these onto `413` / `408` / `400` responses or a quiet close.
#[derive(Debug)]
pub enum ReadError {
    /// The declared body exceeds the configured cap → `413`.
    TooLarge(String),
    /// The socket timed out after the request line had started → `408`.
    Timeout,
    /// Syntactically invalid request → `400`.
    Malformed(String),
    /// The peer vanished mid-request — close without a response.
    Disconnect,
}

/// One parsed HTTP request.
#[derive(Debug, Clone, Default)]
pub struct Request {
    /// Request method, uppercase as received (`GET`, `HEAD`, ...).
    pub method: String,
    /// Path component of the target, percent-**encoded** as received —
    /// split into segments first, then decode each (see
    /// [`Request::segments`]) so an encoded `/` inside a field name does
    /// not change the route shape.
    pub path: String,
    /// Decoded query parameters in order of appearance.
    pub query: Vec<(String, String)>,
    /// Headers with lowercased names, values trimmed.
    pub headers: Vec<(String, String)>,
    /// True when the request (or its HTTP version) asks to close the
    /// connection after the response.
    pub close: bool,
    /// Request body (`Content-Length`-framed; empty for body-less
    /// requests).
    pub body: Vec<u8>,
}

impl Request {
    /// Build a GET request from a target like `/v1/artifacts?x=1` — the
    /// entry point handler unit tests and benches use to exercise routing
    /// without a socket.
    pub fn get(target: &str) -> Request {
        let (path, query) = parse_target(target);
        Request { method: "GET".to_string(), path, query, ..Default::default() }
    }

    /// First value of query parameter `key`, if present.
    pub fn query_param(&self, key: &str) -> Option<&str> {
        self.query.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Value of header `key` (lowercase), if present.
    pub fn header(&self, key: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Percent-decoded path segments (empty segments dropped, so
    /// `/v1//artifacts/` and `/v1/artifacts` route identically).
    pub fn segments(&self) -> Vec<String> {
        self.path
            .split('/')
            .filter(|s| !s.is_empty())
            .map(|s| percent_decode(s, false))
            .collect()
    }
}

/// Read one CRLF-terminated line with the byte cap enforced *while*
/// reading: a newline-free flood errors out at `cap` bytes instead of
/// buffering unbounded input. `Ok(None)` is EOF before any byte.
fn read_line_capped<R: BufRead>(r: &mut R, cap: usize) -> std::io::Result<Option<String>> {
    let mut line = String::new();
    let n = r.by_ref().take(cap as u64).read_line(&mut line)?;
    if n == 0 {
        return Ok(None);
    }
    if n >= cap && !line.ends_with('\n') {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "line exceeds the size cap",
        ));
    }
    Ok(Some(line))
}

/// Read one request from `r`. `Ok(None)` means the connection ended
/// cleanly (EOF before a request line, or an idle-timeout/reset while
/// waiting for one); errors mean a malformed request the caller should
/// answer with 400 and close on. Compatibility wrapper over
/// [`read_request_limited`] with the default body cap.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Option<Request>> {
    match read_request_limited(r, MAX_DRAIN_BODY) {
        Ok(v) => Ok(v),
        Err(ReadError::TooLarge(m)) | Err(ReadError::Malformed(m)) => {
            Err(SzError::config(m))
        }
        Err(ReadError::Timeout) => Err(SzError::config("timed out mid-request")),
        Err(ReadError::Disconnect) => {
            Err(SzError::corrupt("connection closed mid-request"))
        }
    }
}

/// True for I/O error kinds meaning the socket timed out under a
/// configured read timeout.
fn is_timeout(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// True for I/O error kinds meaning the peer went away.
fn is_disconnect(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::UnexpectedEof
            | std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::BrokenPipe
    )
}

/// Read one request from `r`, capturing a `Content-Length`-framed body of
/// at most `max_body` bytes into [`Request::body`]. `Ok(None)` means the
/// connection ended cleanly *before a request line* (EOF, idle timeout,
/// reset — the keep-alive close path); every later failure is classified
/// as a [`ReadError`] so the server can answer `413` (body over the cap),
/// `408` (timed out mid-request), `400` (malformed), or close quietly on
/// a mid-request disconnect.
pub fn read_request_limited<R: BufRead>(
    r: &mut R,
    max_body: usize,
) -> std::result::Result<Option<Request>, ReadError> {
    let mut line = String::new();
    // tolerate stray blank lines between pipelined requests (RFC 9112 §2.2)
    for _ in 0..4 {
        match read_line_capped(r, MAX_LINE) {
            Ok(None) => return Ok(None),
            Ok(Some(l)) => line = l,
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                return Err(ReadError::Malformed("request line too long".to_string()))
            }
            Err(e) if is_timeout(e.kind()) || is_disconnect(e.kind()) => {
                return Ok(None)
            }
            Err(e) => return Err(ReadError::Malformed(e.to_string())),
        }
        if !line.trim_end_matches(['\r', '\n']).is_empty() {
            break;
        }
    }
    let request_line = line.trim_end_matches(['\r', '\n']).to_string();
    let mut parts = request_line.split_whitespace();
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) => (m, t, v),
            _ => {
                return Err(ReadError::Malformed(format!(
                    "malformed request line '{request_line}'"
                )))
            }
        };
    if !target.starts_with('/') {
        return Err(ReadError::Malformed(format!(
            "request target '{target}' not a path"
        )));
    }
    let http10 = match version {
        "HTTP/1.1" => false,
        "HTTP/1.0" => true,
        other => {
            return Err(ReadError::Malformed(format!(
                "unsupported version '{other}'"
            )))
        }
    };
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let h = match read_line_capped(r, MAX_LINE) {
            Ok(None) => return Err(ReadError::Disconnect),
            Ok(Some(l)) => l,
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                return Err(ReadError::Malformed("header line too long".to_string()))
            }
            Err(e) if is_timeout(e.kind()) => return Err(ReadError::Timeout),
            Err(e) if is_disconnect(e.kind()) => return Err(ReadError::Disconnect),
            Err(e) => return Err(ReadError::Malformed(e.to_string())),
        };
        let h = h.trim_end_matches(['\r', '\n']);
        if h.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(ReadError::Malformed("too many headers".to_string()));
        }
        let (name, value) = h.split_once(':').ok_or_else(|| {
            ReadError::Malformed(format!("malformed header '{h}'"))
        })?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    // bodies are Content-Length-framed only; the cap is enforced before
    // the buffer is sized from the untrusted declared length
    let content_length: usize = match headers
        .iter()
        .find(|(k, _)| k == "content-length")
    {
        Some((_, v)) => v.parse().map_err(|_| {
            ReadError::Malformed(format!("bad content-length '{v}'"))
        })?,
        None => 0,
    };
    if content_length > max_body {
        return Err(ReadError::TooLarge(format!(
            "request body of {content_length} bytes exceeds the {max_body}-byte limit"
        )));
    }
    let mut body = Vec::new();
    if content_length > 0 {
        body = vec![0u8; content_length];
        if let Err(e) = std::io::Read::read_exact(r, &mut body) {
            if is_timeout(e.kind()) {
                return Err(ReadError::Timeout);
            }
            if is_disconnect(e.kind()) {
                return Err(ReadError::Disconnect);
            }
            return Err(ReadError::Malformed(e.to_string()));
        }
    }
    let connection = headers
        .iter()
        .find(|(k, _)| k == "connection")
        .map(|(_, v)| v.to_ascii_lowercase());
    let close = match connection.as_deref() {
        Some("close") => true,
        Some("keep-alive") => false,
        _ => http10, // 1.1 defaults to keep-alive, 1.0 to close
    };
    let (path, query) = parse_target(target);
    Ok(Some(Request {
        method: method.to_string(),
        path,
        query,
        headers,
        close,
        body,
    }))
}

/// Split a request target into its raw path and decoded query pairs.
pub fn parse_target(target: &str) -> (String, Vec<(String, String)>) {
    let (path, q) = target.split_once('?').unwrap_or((target, ""));
    let query = q
        .split('&')
        .filter(|p| !p.is_empty())
        .map(|p| {
            let (k, v) = p.split_once('=').unwrap_or((p, ""));
            (percent_decode(k, true), percent_decode(v, true))
        })
        .collect();
    (path.to_string(), query)
}

/// Percent-decode `s`; `+` decodes to space only in query strings.
/// Malformed escapes pass through literally rather than failing the whole
/// request — the path simply won't match any artifact.
pub fn percent_decode(s: &str, plus_as_space: bool) -> String {
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while let Some(&c) = b.get(i) {
        match c {
            b'%' => {
                let hex = |c: u8| (c as char).to_digit(16);
                let pair = (
                    b.get(i + 1).copied().and_then(hex),
                    b.get(i + 2).copied().and_then(hex),
                );
                match pair {
                    (Some(hi), Some(lo)) => {
                        out.push((hi * 16 + lo) as u8);
                        i += 3;
                    }
                    _ => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b'+' if plus_as_space => {
                out.push(b' ');
                i += 1;
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// One HTTP response: status, extra headers, body. `Content-Length` and
/// `Connection` are emitted by [`Response::write_to`]; everything else
/// (including `Content-Type`) lives in `headers`.
#[derive(Debug, Clone)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// Response headers (`Content-Type` included).
    pub headers: Vec<(String, String)>,
    /// Response body bytes.
    pub body: Vec<u8>,
}

impl Response {
    /// JSON response with the given pre-serialized body.
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            headers: vec![("Content-Type".to_string(), "application/json".to_string())],
            body: body.into_bytes(),
        }
    }

    /// Plain-text response with an explicit content type (the Prometheus
    /// exposition endpoint carries a versioned `text/plain` type).
    pub fn text(status: u16, content_type: &str, body: String) -> Response {
        Response {
            status,
            headers: vec![("Content-Type".to_string(), content_type.to_string())],
            body: body.into_bytes(),
        }
    }

    /// Binary response (`application/octet-stream`).
    pub fn octets(body: Vec<u8>) -> Response {
        Response {
            status: 200,
            headers: vec![(
                "Content-Type".to_string(),
                "application/octet-stream".to_string(),
            )],
            body,
        }
    }

    /// Empty `304 Not Modified` — conditional-GET short circuit; the
    /// caller re-attaches the validator (`ETag`) header.
    pub fn not_modified() -> Response {
        Response { status: 304, headers: Vec::new(), body: Vec::new() }
    }

    /// Error response with the API's uniform JSON error body:
    /// `{"error":{"status":N,"message":"..."}}`.
    pub fn error(status: u16, message: &str) -> Response {
        Response::json(
            status,
            format!(
                "{{\"error\":{{\"status\":{status},\"message\":\"{}\"}}}}",
                json_escape(message)
            ),
        )
    }

    /// Append a header (builder style). Header values may derive from
    /// artifact-controlled strings (field and pipeline names), so CR/LF
    /// are stripped — a crafted container cannot split the response
    /// stream or inject headers.
    pub fn with_header(mut self, name: &str, value: impl Into<String>) -> Response {
        let value: String = value
            .into()
            .chars()
            .filter(|c| *c != '\r' && *c != '\n')
            .collect();
        self.headers.push((name.to_string(), value));
        self
    }

    /// First value of header `name` (case-insensitive), if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Serialize onto `w`. `head_only` suppresses the body (HEAD
    /// semantics: full headers, `Content-Length` of the would-be body).
    pub fn write_to(
        &self,
        w: &mut impl Write,
        close: bool,
        head_only: bool,
    ) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nServer: sz3\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            status_text(self.status),
            self.body.len(),
            if close { "close" } else { "keep-alive" }
        );
        for (k, v) in &self.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        w.write_all(head.as_bytes())?;
        if !head_only {
            w.write_all(&self.body)?;
        }
        w.flush()
    }
}

/// Reason phrase for the status codes the API emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        204 => "No Content",
        206 => "Partial Content",
        304 => "Not Modified",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        416 => "Range Not Satisfiable",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Escape `s` for embedding inside a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Option<Request>> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_request_line_query_and_headers() {
        let r = parse(
            "GET /v1/artifacts/nyx/fields/density?rows=3..9&format=json HTTP/1.1\r\n\
             Host: localhost\r\nX-Thing: a b \r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(
            r.segments(),
            vec!["v1", "artifacts", "nyx", "fields", "density"]
        );
        assert_eq!(r.query_param("rows"), Some("3..9"));
        assert_eq!(r.query_param("format"), Some("json"));
        assert_eq!(r.header("x-thing"), Some("a b"));
        assert!(!r.close, "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn percent_decoding_segments_and_query() {
        let r = parse(
            "GET /v1/artifacts/run%201/fields/ff%7Cff?note=a+b%21 HTTP/1.1\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        let segs = r.segments();
        assert_eq!(segs[2], "run 1");
        assert_eq!(segs[4], "ff|ff");
        assert_eq!(r.query_param("note"), Some("a b!"));
        // malformed escapes pass through instead of failing the request
        assert_eq!(percent_decode("100%", false), "100%");
        assert_eq!(percent_decode("%zz", false), "%zz");
    }

    #[test]
    fn eof_and_close_semantics() {
        assert!(parse("").unwrap().is_none(), "clean EOF is not an error");
        let r = parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().unwrap();
        assert!(r.close);
        let r = parse("GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(r.close, "HTTP/1.0 defaults to close");
        let r = parse("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!r.close);
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(parse("GET\r\n\r\n").is_err(), "short request line");
        assert!(parse("GET noslash HTTP/1.1\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/2\r\n\r\n").is_err(), "unsupported version");
        assert!(parse("GET / HTTP/1.1\r\nbadheader\r\n\r\n").is_err());
        assert!(
            parse("GET / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n").is_err(),
            "oversized body rejected"
        );
        assert!(parse("GET / HTTP/1.1\r\nHost: x\r\n").is_err(), "eof mid-headers");
        // newline-free floods error at the cap instead of buffering forever
        let flood = format!("GET /{} HTTP/1.1", "a".repeat(3 * MAX_LINE));
        assert!(parse(&flood).is_err(), "unbounded request line rejected");
        let flood = format!("GET / HTTP/1.1\r\nX-H: {}", "b".repeat(3 * MAX_LINE));
        assert!(parse(&flood).is_err(), "unbounded header line rejected");
    }

    #[test]
    fn header_values_cannot_split_responses() {
        let resp = Response::json(200, "{}".to_string())
            .with_header("X-SZ3-Field", "ff\r\nX-Evil: 1\r\n\r\nHTTP/1.1 200 OK");
        assert_eq!(resp.header("X-SZ3-Field"), Some("ffX-Evil: 1HTTP/1.1 200 OK"));
        let mut buf = Vec::new();
        resp.write_to(&mut buf, true, false).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(
            !text.contains("\r\nX-Evil"),
            "injected text must not start a header line of its own"
        );
        assert_eq!(text.matches("\r\n\r\n").count(), 1, "exactly one head/body boundary");
    }

    #[test]
    fn response_serializes_with_length_and_connection() {
        let resp = Response::json(200, "{\"ok\":true}".to_string())
            .with_header("X-SZ3-Dims", "4,12");
        let mut buf = Vec::new();
        resp.write_to(&mut buf, false, false).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 11\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.contains("X-SZ3-Dims: 4,12\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"));
        // HEAD keeps the headers, drops the body
        let mut buf = Vec::new();
        resp.write_to(&mut buf, true, true).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("Content-Length: 11\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("\r\n\r\n"));
    }

    #[test]
    fn bodies_are_captured_classified_and_capped() {
        // a Content-Length-framed body lands in req.body
        let raw = b"PUT /v1/artifacts/x HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";
        let req = read_request_limited(&mut Cursor::new(raw.to_vec()), 64)
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "PUT");
        assert_eq!(req.body, b"hello");
        // a declared length over the cap classifies TooLarge before any
        // body byte is read or buffered
        let raw = b"PUT /x HTTP/1.1\r\nContent-Length: 99\r\n\r\n";
        match read_request_limited(&mut Cursor::new(raw.to_vec()), 64) {
            Err(ReadError::TooLarge(_)) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // a body truncated by a vanished peer classifies Disconnect (the
        // crash-safety path: no response, no publish)
        let raw = b"PUT /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc";
        match read_request_limited(&mut Cursor::new(raw.to_vec()), 64) {
            Err(ReadError::Disconnect) => {}
            other => panic!("expected Disconnect, got {other:?}"),
        }
        // the write-path status vocabulary has reason phrases
        for (code, text) in [
            (201, "Created"),
            (408, "Request Timeout"),
            (409, "Conflict"),
            (429, "Too Many Requests"),
            (503, "Service Unavailable"),
        ] {
            assert_eq!(status_text(code), text);
        }
    }

    #[test]
    fn error_body_is_uniform_json() {
        let resp = Response::error(416, "rows 9..99 outside \"t\"");
        let body = String::from_utf8(resp.body.clone()).unwrap();
        assert_eq!(
            body,
            "{\"error\":{\"status\":416,\"message\":\"rows 9..99 outside \\\"t\\\"\"}}"
        );
        // the crate's own JSON parser accepts it
        let parsed = crate::config::Json::parse(&body).unwrap();
        let err = parsed.get("error").unwrap();
        assert_eq!(err.get("status").unwrap().as_usize(), Some(416));
    }
}
