//! Minimal blocking HTTP/1.1 client for exercising the serve layer —
//! benches, examples, and the loopback integration tests all drive the
//! server through this instead of each hand-rolling socket I/O. Supports
//! exactly what the server emits: status line, headers, `Content-Length`
//! framed bodies, keep-alive connection reuse.

use crate::error::{Result, SzError};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// A parsed HTTP response.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    /// Status code.
    pub status: u16,
    /// Headers with lowercased names.
    pub headers: Vec<(String, String)>,
    /// Body bytes (exactly `Content-Length` of them).
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// Value of header `name` (lowercase), if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Body as UTF-8 (for the JSON endpoints).
    pub fn text(&self) -> Result<&str> {
        std::str::from_utf8(&self.body)
            .map_err(|_| SzError::corrupt("response body is not UTF-8"))
    }
}

/// One keep-alive connection to a server.
pub struct HttpClient {
    stream: BufReader<TcpStream>,
}

impl HttpClient {
    /// Connect to `addr`.
    pub fn connect(addr: SocketAddr) -> Result<HttpClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| SzError::config(format!("connecting {addr}: {e}")))?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(HttpClient { stream: BufReader::new(stream) })
    }

    /// Issue `GET target` on this connection and read the full response.
    pub fn get(&mut self, target: &str) -> Result<HttpResponse> {
        self.get_with_headers(target, &[])
    }

    /// `GET target` with extra request headers (e.g. `If-None-Match` for
    /// conditional requests against the raw-chunk ETags).
    pub fn get_with_headers(
        &mut self,
        target: &str,
        extra: &[(&str, &str)],
    ) -> Result<HttpResponse> {
        self.request("GET", target, extra, &[])
    }

    /// `PUT target` with a body (the streaming-ingest endpoint).
    pub fn put(&mut self, target: &str, body: &[u8]) -> Result<HttpResponse> {
        self.request("PUT", target, &[], body)
    }

    /// `DELETE target`.
    pub fn delete(&mut self, target: &str) -> Result<HttpResponse> {
        self.request("DELETE", target, &[], &[])
    }

    /// `POST target` with a body (admin endpoints).
    pub fn post(&mut self, target: &str, body: &[u8]) -> Result<HttpResponse> {
        self.request("POST", target, &[], body)
    }

    /// Issue an arbitrary request on this keep-alive connection. A
    /// `Content-Length` header is always sent so the server can frame
    /// the body (including an explicit `0` for body-less methods).
    pub fn request(
        &mut self,
        method: &str,
        target: &str,
        extra: &[(&str, &str)],
        body: &[u8],
    ) -> Result<HttpResponse> {
        let mut request = format!(
            "{method} {target} HTTP/1.1\r\nHost: sz3\r\nConnection: keep-alive\r\n"
        );
        for (name, value) in extra {
            request.push_str(name);
            request.push_str(": ");
            request.push_str(value);
            request.push_str("\r\n");
        }
        request.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
        self.stream.get_mut().write_all(request.as_bytes())?;
        if !body.is_empty() {
            self.stream.get_mut().write_all(body)?;
        }
        self.stream.get_mut().flush()?;
        self.read_response()
    }

    fn read_response(&mut self) -> Result<HttpResponse> {
        let mut line = String::new();
        if self.stream.read_line(&mut line)? == 0 {
            return Err(SzError::corrupt("server closed before the status line"));
        }
        let line = line.trim_end_matches(['\r', '\n']);
        let mut parts = line.splitn(3, ' ');
        let (proto, code) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
        if !proto.starts_with("HTTP/1.") {
            return Err(SzError::corrupt(format!("bad status line '{line}'")));
        }
        let status: u16 = code
            .parse()
            .map_err(|_| SzError::corrupt(format!("bad status code '{code}'")))?;
        let mut headers: Vec<(String, String)> = Vec::new();
        loop {
            let mut h = String::new();
            if self.stream.read_line(&mut h)? == 0 {
                return Err(SzError::corrupt("server closed mid-headers"));
            }
            let h = h.trim_end_matches(['\r', '\n']);
            if h.is_empty() {
                break;
            }
            if let Some((name, value)) = h.split_once(':') {
                headers
                    .push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
            }
        }
        let len: usize = headers
            .iter()
            .find(|(k, _)| k == "content-length")
            .and_then(|(_, v)| v.parse().ok())
            .ok_or_else(|| SzError::corrupt("response without content-length"))?;
        let mut body = vec![0u8; len];
        self.stream.read_exact(&mut body)?;
        Ok(HttpResponse { status, headers, body })
    }
}

/// One-shot convenience: fresh connection, single GET, drop.
pub fn get_once(addr: SocketAddr, target: &str) -> Result<HttpResponse> {
    HttpClient::connect(addr)?.get(target)
}
