//! Fixed-size worker thread pool over an `mpsc` channel — the connection
//! executor behind [`super::serve`] (a thread-per-connection model would
//! let a connection flood exhaust the process; a fixed pool makes
//! `--threads` the concurrency ceiling).
//!
//! Jobs queue in the channel when all workers are busy, so accepted
//! connections are never dropped, only delayed. Dropping the pool closes
//! the channel and joins every worker, which is what gives the server a
//! deterministic shutdown: queued connections finish, then the threads
//! exit.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of named worker threads pulling jobs from a shared queue.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `threads` workers (at least one).
    pub fn new(threads: usize) -> ThreadPool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("sz3-http-{i}"))
                    .spawn(move || loop {
                        // hold the lock only for the dequeue, not the job
                        let job = match rx.lock() {
                            Ok(guard) => guard.recv(),
                            Err(_) => break, // poisoned: a peer panicked mid-dequeue
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn http worker thread")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Worker count.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job; runs as soon as a worker frees up. No-op after the
    /// pool has begun shutting down.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        if let Some(tx) = &self.tx {
            // audit:allow(swallow, reason = "send fails only while the pool is dropping, when new work is documented as a no-op")
            let _ = tx.send(Box::new(job));
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers drain then exit
        for w in self.workers.drain(..) {
            // audit:allow(swallow, reason = "drop path; a panicked worker is already gone and must not abort the drain")
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_job_and_joins_on_drop() {
        let hits = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(3);
            assert_eq!(pool.size(), 3);
            for _ in 0..50 {
                let hits = Arc::clone(&hits);
                pool.execute(move || {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop joins: all queued jobs must have run
        assert_eq!(hits.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let (tx, rx) = channel();
        pool.execute(move || tx.send(7usize).unwrap());
        assert_eq!(rx.recv().unwrap(), 7);
    }
}
