//! Minimal property-testing helper (proptest is unavailable offline).
//!
//! `cases(n, seed, f)` runs `f` against `n` independently seeded PRNGs and
//! reports the failing case index + seed on panic, so failures are
//! reproducible with `case_with_seed`.

use super::rng::Pcg32;

/// Run `n` property cases. Each case receives its own deterministic RNG.
/// Panics (re-raising the property's panic) with the case seed on failure.
pub fn cases<F: FnMut(&mut Pcg32)>(n: usize, seed: u64, mut f: F) {
    for i in 0..n {
        let case_seed = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(i as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Pcg32::seeded(case_seed);
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property failed at case {i} (seed {case_seed:#x}); \
                       reproduce with prop::case_with_seed({case_seed:#x}, ..)");
            std::panic::resume_unwind(e);
        }
    }
}

/// Re-run a single failing case by seed.
pub fn case_with_seed<F: Fn(&mut Pcg32)>(seed: u64, f: F) {
    let mut rng = Pcg32::seeded(seed);
    f(&mut rng);
}

/// Random vector of f32 with mixed magnitudes (including subnormal-ish,
/// zero, negative) — stress data for compressors.
pub fn vec_f32(rng: &mut Pcg32, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| {
            match rng.below(10) {
                0 => 0.0,
                1 => rng.uniform(-1e-6, 1e-6) as f32,
                2 => rng.uniform(-1e6, 1e6) as f32,
                _ => rng.uniform(-100.0, 100.0) as f32,
            }
        })
        .collect()
}

/// Random smooth field (random low-frequency Fourier modes) — data that
/// predictors should do well on.
pub fn smooth_field(rng: &mut Pcg32, dims: &[usize]) -> Vec<f32> {
    let n: usize = dims.iter().product();
    let modes: Vec<(f64, Vec<f64>, f64)> = (0..6)
        .map(|_| {
            let amp = rng.uniform(0.1, 2.0);
            let freqs: Vec<f64> = dims.iter().map(|_| rng.uniform(0.5, 4.0)).collect();
            let phase = rng.uniform(0.0, std::f64::consts::TAU);
            (amp, freqs, phase)
        })
        .collect();
    let mut out = vec![0f32; n];
    let mut idx = vec![0usize; dims.len()];
    for v in out.iter_mut() {
        let mut val = 0.0;
        for (amp, freqs, phase) in &modes {
            let arg: f64 = idx
                .iter()
                .zip(dims.iter())
                .zip(freqs.iter())
                .map(|((&i, &d), &f)| f * i as f64 / d as f64 * std::f64::consts::TAU)
                .sum::<f64>()
                + phase;
            val += amp * arg.sin();
        }
        *v = val as f32;
        // advance multi-index
        for d in (0..dims.len()).rev() {
            idx[d] += 1;
            if idx[d] < dims[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    out
}

/// Random byte vector.
pub fn vec_u8(rng: &mut Pcg32, len: usize) -> Vec<u8> {
    (0..len).map(|_| rng.next_u32() as u8).collect()
}

/// Byte vector with repetitive structure (compressible).
pub fn compressible_u8(rng: &mut Pcg32, len: usize) -> Vec<u8> {
    let motif: Vec<u8> = (0..rng.below(32) + 4).map(|_| rng.next_u32() as u8).collect();
    let mut out = Vec::with_capacity(len);
    while out.len() < len {
        if rng.below(4) == 0 {
            out.push(rng.next_u32() as u8);
        } else {
            let take = (rng.below(motif.len()) + 1).min(len - out.len());
            out.extend_from_slice(&motif[..take]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_run_deterministically() {
        let mut seen = Vec::new();
        cases(5, 1, |rng| {
            let _ = rng.next_u32();
        });
        cases(5, 1, |rng| seen.push(rng.next_u32()));
        let mut seen2 = Vec::new();
        cases(5, 1, |rng| seen2.push(rng.next_u32()));
        assert_eq!(seen, seen2);
    }

    #[test]
    fn smooth_field_shape() {
        let mut rng = Pcg32::seeded(11);
        let f = smooth_field(&mut rng, &[4, 5, 6]);
        assert_eq!(f.len(), 120);
        assert!(f.iter().all(|x| x.is_finite()));
    }
}
