//! Runtime-dispatched vector kernels for the hot inner loops shared across
//! pipeline families (the SZx design point, arXiv 2201.13020: flat,
//! vectorizable loops instead of pointwise stage calls).
//!
//! Every kernel exists in exactly one source form — a `#[inline(always)]`
//! body written as a flat slice loop — compiled twice: once at the crate's
//! baseline target features and once inside an `#[target_feature(enable =
//! "avx2")]` wrapper, selected at runtime with `is_x86_feature_detected!`.
//! Because both compilations execute the *identical* sequence of IEEE-754
//! operations (AVX2 does not imply FMA, and Rust never contracts
//! floating-point expressions), the two paths are bit-identical by
//! construction; `*_scalar` variants stay public so the property tests can
//! pin that equivalence on machines where the vector path is live.
//!
//! Kernels: linear quantization (the residual→bin loop of
//! [`crate::quantizer::LinearQuantizer`] and the blockwise fast paths),
//! order-1 Lorenzo residual/reconstruction, series delta residual/apply
//! ([`crate::container::delta`]), block min/max scan (the `constblock`
//! family's constant detection) and slice-by-8 CRC-32
//! ([`crate::util::crc32`]).

use crate::data::Scalar;
use std::sync::OnceLock;

/// Quantization code reserved for out-of-range ("unpredictable") values.
/// Matches `crate::quantizer::UNPREDICTABLE` (asserted at compile time at
/// the use site).
pub const ESCAPE: u32 = 0;

/// True when the AVX2 fast paths are selected on this CPU.
pub fn avx2_active() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// A short human label for the selected dispatch tier (for bench output).
pub fn dispatch_label() -> &'static str {
    if avx2_active() {
        "avx2"
    } else {
        "scalar"
    }
}

macro_rules! dispatch {
    // Generate the public dispatched entry + avx2 wrapper + public scalar
    // variant around an `#[inline(always)]` body function.
    ($(#[$doc:meta])* $name:ident, $name_scalar:ident, $body:ident,
     fn($($arg:ident : $ty:ty),*) $(-> $ret:ty)?) => {
        $(#[$doc])*
        pub fn $name($($arg: $ty),*) $(-> $ret)? {
            #[cfg(target_arch = "x86_64")]
            {
                if is_x86_feature_detected!("avx2") {
                    #[target_feature(enable = "avx2")]
                    unsafe fn avx2($($arg: $ty),*) $(-> $ret)? {
                        $body($($arg),*)
                    }
                    // SAFETY: reached only when the CPU reports AVX2.
                    return unsafe { avx2($($arg),*) };
                }
            }
            $body($($arg),*)
        }

        /// Always-scalar variant of the same kernel (bit-identity pin).
        pub fn $name_scalar($($arg: $ty),*) $(-> $ret)? {
            $body($($arg),*)
        }
    };
}

// ---------------------------------------------------------------- quantize

#[inline(always)]
fn linear_quantize_body<T: Scalar>(
    values: &mut [T],
    preds: &[f64],
    eb: f64,
    radius: u32,
    codes: &mut [u32],
) -> usize {
    let radius_f = radius as f64;
    let radius_i = radius as i64;
    let mut escapes = 0usize;
    for ((v, &p), c) in values.iter_mut().zip(preds).zip(codes.iter_mut()) {
        let x = v.to_f64();
        let diff = x - p;
        let q = (diff / (2.0 * eb)).round();
        let mut code = ESCAPE;
        if q.abs() < radius_f {
            let rec = T::from_f64(p + q * 2.0 * eb);
            if (rec.to_f64() - x).abs() <= eb {
                code = (q as i64 + radius_i) as u32;
                *v = rec;
            }
        }
        escapes += usize::from(code == ESCAPE);
        *c = code;
    }
    escapes
}

/// Generic-inner quantize: monomorphic wrappers below get the dispatch.
#[inline(always)]
fn quantize_inner<T: Scalar>(
    values: &mut [T],
    preds: &[f64],
    eb: f64,
    radius: u32,
    codes: &mut [u32],
) -> usize {
    linear_quantize_body(values, preds, eb, radius, codes)
}

macro_rules! quantize_for {
    ($(#[$doc:meta])* $name:ident, $name_scalar:ident, $body:ident, $t:ty) => {
        #[inline(always)]
        fn $body(
            values: &mut [$t],
            preds: &[f64],
            eb: f64,
            radius: u32,
            codes: &mut [u32],
        ) -> usize {
            quantize_inner(values, preds, eb, radius, codes)
        }
        dispatch! {
            $(#[$doc])*
            $name, $name_scalar, $body,
            fn(values: &mut [$t], preds: &[f64], eb: f64, radius: u32,
               codes: &mut [u32]) -> usize
        }
    };
}

quantize_for! {
    /// Linear-scaling quantization of a row of `f32` values against
    /// precomputed predictions. Writes the recovered value over each
    /// in-range input (out-of-range inputs keep their original value so
    /// the caller can collect them as unpredictables, in order) and the
    /// bin code into `codes` ([`ESCAPE`] marks out-of-range). Returns the
    /// escape count. Per-element semantics are exactly those of
    /// `LinearQuantizer::quantize`.
    linear_quantize_f32, linear_quantize_f32_scalar, lq_f32_body, f32
}
quantize_for! {
    /// [`linear_quantize_f32`] for `f64` rows.
    linear_quantize_f64, linear_quantize_f64_scalar, lq_f64_body, f64
}
quantize_for! {
    /// [`linear_quantize_f32`] for `i32` rows.
    linear_quantize_i32, linear_quantize_i32_scalar, lq_i32_body, i32
}

/// Reinterpret `&mut [T]` as `&mut [U]` once `TypeId` equality has proven
/// `T == U` (same type ⇒ same layout; the lifetime is untouched).
macro_rules! reslice_if {
    ($values:ident, $t:ty, $kernel:ident, $preds:ident, $eb:ident, $radius:ident, $codes:ident) => {
        if std::any::TypeId::of::<T>() == std::any::TypeId::of::<$t>() {
            // SAFETY: TypeId equality above proves T is exactly $t.
            let v = unsafe { &mut *($values as *mut [T] as *mut [$t]) };
            return $kernel(v, $preds, $eb, $radius, $codes);
        }
    };
}

/// Dtype-generic front door for the linear quantization kernel; routes the
/// three wire scalar types to their monomorphic dispatched entries and any
/// future [`Scalar`] impl to the shared scalar body.
pub fn linear_quantize<T: Scalar>(
    values: &mut [T],
    preds: &[f64],
    eb: f64,
    radius: u32,
    codes: &mut [u32],
) -> usize {
    reslice_if!(values, f32, linear_quantize_f32, preds, eb, radius, codes);
    reslice_if!(values, f64, linear_quantize_f64, preds, eb, radius, codes);
    reslice_if!(values, i32, linear_quantize_i32, preds, eb, radius, codes);
    linear_quantize_body(values, preds, eb, radius, codes)
}

// ----------------------------------------------------------------- lorenzo

#[inline(always)]
fn lorenzo1_residual_body(values: &[f64], out: &mut [f64]) {
    // out[i] = v[i] - v[i-1]; the first element keeps its value (predict 0).
    let mut prev = 0.0;
    for (o, &v) in out.iter_mut().zip(values) {
        *o = v - prev;
        prev = v;
    }
}

dispatch! {
    /// Order-1 1-D Lorenzo residual over original values (the estimation /
    /// proxy form: each point predicted by its raw left neighbor).
    lorenzo1_residual, lorenzo1_residual_scalar, lorenzo1_residual_body,
    fn(values: &[f64], out: &mut [f64])
}

#[inline(always)]
fn lorenzo1_abs_sum_body(values: &[f64]) -> f64 {
    let mut prev = 0.0;
    let mut sum = 0.0;
    for &v in values {
        sum += (v - prev).abs();
        prev = v;
    }
    sum
}

dispatch! {
    /// Sum of |order-1 Lorenzo residuals| (the adaptive selector's
    /// first-difference signal) without materializing the residual row.
    lorenzo1_abs_sum, lorenzo1_abs_sum_scalar, lorenzo1_abs_sum_body,
    fn(values: &[f64]) -> f64
}

/// Reconstruct values from order-1 residuals in place (prefix sum). The
/// loop is inherently sequential, so there is no vector variant — it lives
/// here so residual/reconstruct stay one audited pair.
pub fn lorenzo1_apply(deltas: &mut [f64]) {
    let mut acc = 0.0;
    for d in deltas.iter_mut() {
        acc += *d;
        *d = acc;
    }
}

// ------------------------------------------------------------------- delta

#[inline(always)]
fn delta_sub_f32_body(original: &[f32], baseline: &[f32], out: &mut [f32]) {
    for ((&x, &y), o) in original.iter().zip(baseline).zip(out.iter_mut()) {
        *o = (f64::from(x) - f64::from(y)) as f32;
    }
}
dispatch! {
    /// Series delta residual `original - baseline` for f32 fields
    /// (computed in f64, matching `container::delta::residual`).
    delta_sub_f32, delta_sub_f32_scalar, delta_sub_f32_body,
    fn(original: &[f32], baseline: &[f32], out: &mut [f32])
}

#[inline(always)]
fn delta_add_f32_body(baseline: &[f32], residual: &[f32], out: &mut [f32]) {
    for ((&y, &d), o) in baseline.iter().zip(residual).zip(out.iter_mut()) {
        *o = (f64::from(y) + f64::from(d)) as f32;
    }
}
dispatch! {
    /// Series delta reconstruction `baseline + residual` for f32 fields
    /// (f64 domain, matching `container::delta::apply`).
    delta_add_f32, delta_add_f32_scalar, delta_add_f32_body,
    fn(baseline: &[f32], residual: &[f32], out: &mut [f32])
}

#[inline(always)]
fn delta_sub_f64_body(original: &[f64], baseline: &[f64], out: &mut [f64]) {
    for ((&x, &y), o) in original.iter().zip(baseline).zip(out.iter_mut()) {
        *o = x - y;
    }
}
dispatch! {
    /// Series delta residual for f64 fields.
    delta_sub_f64, delta_sub_f64_scalar, delta_sub_f64_body,
    fn(original: &[f64], baseline: &[f64], out: &mut [f64])
}

#[inline(always)]
fn delta_add_f64_body(baseline: &[f64], residual: &[f64], out: &mut [f64]) {
    for ((&y, &d), o) in baseline.iter().zip(residual).zip(out.iter_mut()) {
        *o = y + d;
    }
}
dispatch! {
    /// Series delta reconstruction for f64 fields.
    delta_add_f64, delta_add_f64_scalar, delta_add_f64_body,
    fn(baseline: &[f64], residual: &[f64], out: &mut [f64])
}

#[inline(always)]
fn delta_sub_i32_body(original: &[i32], baseline: &[i32], out: &mut [i32]) {
    for ((&x, &y), o) in original.iter().zip(baseline).zip(out.iter_mut()) {
        *o = x.wrapping_sub(y);
    }
}
dispatch! {
    /// Integer series delta residual (wrapping, lossless).
    delta_sub_i32, delta_sub_i32_scalar, delta_sub_i32_body,
    fn(original: &[i32], baseline: &[i32], out: &mut [i32])
}

#[inline(always)]
fn delta_add_i32_body(baseline: &[i32], residual: &[i32], out: &mut [i32]) {
    for ((&y, &d), o) in baseline.iter().zip(residual).zip(out.iter_mut()) {
        *o = y.wrapping_add(d);
    }
}
dispatch! {
    /// Integer series delta reconstruction (wrapping, lossless).
    delta_add_i32, delta_add_i32_scalar, delta_add_i32_body,
    fn(baseline: &[i32], residual: &[i32], out: &mut [i32])
}

// ------------------------------------------------------------------ minmax

#[inline(always)]
fn minmax_f64_body(values: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in values {
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    (lo, hi)
}

dispatch! {
    /// Min/max scan of one block (the `constblock` constant test). NaNs
    /// never win a comparison, so an all-NaN block reports the identity
    /// `(+inf, -inf)` and the caller treats it as non-constant.
    minmax_f64, minmax_f64_scalar, minmax_f64_body,
    fn(values: &[f64]) -> (f64, f64)
}

/// Dtype-generic min/max scan in the f64 domain.
pub fn minmax<T: Scalar>(values: &[T]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for v in values {
        let x = v.to_f64();
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    (lo, hi)
}

// ------------------------------------------------------------------- crc32

/// CRC-32 (IEEE, reflected 0xEDB88320) slice-by-8 tables; table 0 is the
/// classic byte-at-a-time table.
fn crc_tables() -> &'static [[u32; 256]; 8] {
    static TABLES: OnceLock<[[u32; 256]; 8]> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut t = [[0u32; 256]; 8];
        for (i, slot) in t[0].iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 == 1 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        for i in 0..256 {
            let mut c = t[0][i];
            for k in 1..8 {
                c = t[0][(c & 0xff) as usize] ^ (c >> 8);
                t[k][i] = c;
            }
        }
        t
    })
}

/// Advance a raw (pre-inverted) CRC-32 state over `bytes`, eight bytes per
/// step. Exactly equivalent to the byte-at-a-time loop over table 0 — the
/// slice-by-8 identity is pinned by tests against [`crc32_update_scalar`].
pub fn crc32_update(mut state: u32, bytes: &[u8]) -> u32 {
    let t = crc_tables();
    let mut chunks = bytes.chunks_exact(8);
    for chunk in &mut chunks {
        let lo = u32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]) ^ state;
        let hi = u32::from_le_bytes([chunk[4], chunk[5], chunk[6], chunk[7]]);
        state = t[7][(lo & 0xff) as usize]
            ^ t[6][((lo >> 8) & 0xff) as usize]
            ^ t[5][((lo >> 16) & 0xff) as usize]
            ^ t[4][(lo >> 24) as usize]
            ^ t[3][(hi & 0xff) as usize]
            ^ t[2][((hi >> 8) & 0xff) as usize]
            ^ t[1][((hi >> 16) & 0xff) as usize]
            ^ t[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        state = t[0][((state ^ u32::from(b)) & 0xff) as usize] ^ (state >> 8);
    }
    state
}

/// Byte-at-a-time reference form of [`crc32_update`] (table 0 only).
pub fn crc32_update_scalar(mut state: u32, bytes: &[u8]) -> u32 {
    let t = crc_tables();
    for &b in bytes {
        state = t[0][((state ^ u32::from(b)) & 0xff) as usize] ^ (state >> 8);
    }
    state
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn quantize_dispatched_matches_scalar_bitexactly() {
        prop::cases(60, 0x51d, |rng| {
            let n = rng.below(300) + 1;
            let eb = 10f64.powf(rng.uniform(-6.0, 0.0));
            let radius = [4u32, 64, 512, 32768][rng.below(4)];
            let data: Vec<f64> = (0..n).map(|_| rng.uniform(-100.0, 100.0)).collect();
            let preds: Vec<f64> = data
                .iter()
                .map(|&d| d + rng.normal() * eb * 10.0_f64.powf(rng.uniform(-1.0, 3.0)))
                .collect();
            let mut v1: Vec<f64> = data.clone();
            let mut v2: Vec<f64> = data.clone();
            let mut c1 = vec![0u32; n];
            let mut c2 = vec![0u32; n];
            let e1 = linear_quantize_f64(&mut v1, &preds, eb, radius, &mut c1);
            let e2 = linear_quantize_f64_scalar(&mut v2, &preds, eb, radius, &mut c2);
            assert_eq!(e1, e2);
            assert_eq!(c1, c2);
            let b1: Vec<u64> = v1.iter().map(|x| x.to_bits()).collect();
            let b2: Vec<u64> = v2.iter().map(|x| x.to_bits()).collect();
            assert_eq!(b1, b2, "dispatched vs scalar diverged ({})", dispatch_label());

            // f32 storage path too (exercises the from_f64 rounding check)
            let df: Vec<f32> = data.iter().map(|&d| d as f32).collect();
            let mut f1 = df.clone();
            let mut f2 = df.clone();
            let ef1 = linear_quantize_f32(&mut f1, &preds, eb, radius, &mut c1);
            let ef2 = linear_quantize_f32_scalar(&mut f2, &preds, eb, radius, &mut c2);
            assert_eq!(ef1, ef2);
            assert_eq!(c1, c2);
            let fb1: Vec<u32> = f1.iter().map(|x| x.to_bits()).collect();
            let fb2: Vec<u32> = f2.iter().map(|x| x.to_bits()).collect();
            assert_eq!(fb1, fb2);
        });
    }

    #[test]
    fn quantize_matches_pointwise_quantizer() {
        use crate::quantizer::{LinearQuantizer, Quantizer};
        prop::cases(40, 0x51e, |rng| {
            let n = rng.below(200) + 1;
            let eb = 10f64.powf(rng.uniform(-5.0, 0.0));
            let data: Vec<f64> = (0..n).map(|_| rng.uniform(-50.0, 50.0)).collect();
            let preds: Vec<f64> =
                data.iter().map(|&d| d + rng.normal() * eb * 4.0).collect();
            let mut q = LinearQuantizer::<f64>::with_radius(eb, 128);
            let mut want_codes = Vec::new();
            let mut want_rec = Vec::new();
            for (&d, &p) in data.iter().zip(&preds) {
                let (code, rec) = q.quantize(d, p);
                want_codes.push(code);
                want_rec.push(rec.to_bits());
            }
            let mut v = data.clone();
            let mut codes = vec![0u32; n];
            linear_quantize_f64(&mut v, &preds, eb, 128, &mut codes);
            assert_eq!(codes, want_codes);
            let got: Vec<u64> = v.iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want_rec, "kernel diverged from LinearQuantizer");
        });
    }

    #[test]
    fn lorenzo_kernels_match_and_roundtrip() {
        prop::cases(40, 0x52a, |rng| {
            let n = rng.below(400) + 1;
            let data: Vec<f64> = (0..n).map(|_| rng.uniform(-1e3, 1e3)).collect();
            let mut r1 = vec![0.0; n];
            let mut r2 = vec![0.0; n];
            lorenzo1_residual(&data, &mut r1);
            lorenzo1_residual_scalar(&data, &mut r2);
            assert_eq!(
                r1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                r2.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            let s1 = lorenzo1_abs_sum(&data);
            let s2 = lorenzo1_abs_sum_scalar(&data);
            assert_eq!(s1.to_bits(), s2.to_bits());
            // reconstruction inverts the residual up to fp associativity
            let mut rec = r1.clone();
            lorenzo1_apply(&mut rec);
            for (a, b) in rec.iter().zip(&data) {
                assert!((a - b).abs() <= 1e-6 * (1.0 + b.abs()));
            }
        });
    }

    #[test]
    fn delta_kernels_match_scalar_bitexactly() {
        prop::cases(40, 0x52b, |rng| {
            let n = rng.below(500) + 1;
            let a: Vec<f32> = (0..n).map(|_| rng.uniform(-1e4, 1e4) as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.uniform(-1e4, 1e4) as f32).collect();
            let mut o1 = vec![0f32; n];
            let mut o2 = vec![0f32; n];
            delta_sub_f32(&a, &b, &mut o1);
            delta_sub_f32_scalar(&a, &b, &mut o2);
            assert_eq!(
                o1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                o2.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            let mut back = vec![0f32; n];
            delta_add_f32(&b, &o1, &mut back);
            let mut back2 = vec![0f32; n];
            delta_add_f32_scalar(&b, &o2, &mut back2);
            assert_eq!(
                back.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                back2.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            let ia: Vec<i32> = (0..n).map(|_| rng.below(1 << 30) as i32 - (1 << 29)).collect();
            let ib: Vec<i32> = (0..n).map(|_| rng.below(1 << 30) as i32 - (1 << 29)).collect();
            let mut d1 = vec![0i32; n];
            let mut d2 = vec![0i32; n];
            delta_sub_i32(&ia, &ib, &mut d1);
            delta_sub_i32_scalar(&ia, &ib, &mut d2);
            assert_eq!(d1, d2);
            let mut r = vec![0i32; n];
            delta_add_i32(&ib, &d1, &mut r);
            assert_eq!(r, ia, "integer delta must be exactly invertible");
        });
    }

    #[test]
    fn minmax_matches_scalar_and_handles_nan() {
        prop::cases(40, 0x52c, |rng| {
            let n = rng.below(600) + 1;
            let data: Vec<f64> = (0..n).map(|_| rng.uniform(-1e6, 1e6)).collect();
            let a = minmax_f64(&data);
            let b = minmax_f64_scalar(&data);
            assert_eq!((a.0.to_bits(), a.1.to_bits()), (b.0.to_bits(), b.1.to_bits()));
            let lo = data.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = data.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(a, (lo, hi));
        });
        let (lo, hi) = minmax_f64(&[f64::NAN, f64::NAN]);
        assert!(lo > hi, "all-NaN block must read as non-constant");
    }

    #[test]
    fn crc_slice8_equals_byte_at_a_time() {
        // known vector (also pinned in util::crc32 against the public API)
        let raw = crc32_update(0xFFFF_FFFF, b"123456789") ^ 0xFFFF_FFFF;
        assert_eq!(raw, 0xCBF4_3926);
        prop::cases(60, 0x52d, |rng| {
            let n = rng.below(4096);
            let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let a = crc32_update(0xFFFF_FFFF, &bytes);
            let b = crc32_update_scalar(0xFFFF_FFFF, &bytes);
            assert_eq!(a, b, "slice-by-8 diverged at n={n}");
            // resumability across arbitrary split points
            let split = rng.below(n + 1);
            let (x, y) = bytes.split_at(split);
            assert_eq!(crc32_update(crc32_update(0xFFFF_FFFF, x), y), a);
        });
    }

    #[test]
    fn generic_quantize_routes_all_dtypes() {
        let preds = vec![0.0f64; 8];
        let mut f = vec![1.0f32; 8];
        let mut codes = vec![0u32; 8];
        let e = linear_quantize(&mut f, &preds, 0.5, 16, &mut codes);
        assert_eq!(e, 0);
        let mut i = vec![3i32; 8];
        let e = linear_quantize(&mut i, &preds, 0.5, 16, &mut codes);
        assert_eq!(e, 0);
        let mut d = vec![2.0f64; 8];
        let e = linear_quantize(&mut d, &preds, 0.5, 16, &mut codes);
        assert_eq!(e, 0);
    }
}
