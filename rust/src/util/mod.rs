//! Utility substrates: PRNG and property-testing helpers.

pub mod prop;
pub mod rng;
