//! Utility substrates: PRNG and property-testing helpers.

pub mod prop;
pub mod rng;

/// Default worker-thread count: one per available core, 4 when the
/// parallelism cannot be queried. Shared by the coordinator config and the
/// parallel container-decompression entry points.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}
