//! Utility substrates: PRNG, property-testing helpers, CRC-32, and the
//! runtime-dispatched SIMD kernel pool.

pub mod crc32;
pub mod prop;
pub mod rng;
pub mod simd;

/// Default worker-thread count: one per available core, 4 when the
/// parallelism cannot be queried. Shared by the coordinator config and the
/// parallel container-decompression entry points.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parse an `A..B` half-open row-range spec — the one grammar shared by
/// `sz3 extract --rows` and the HTTP ROI endpoint's `?rows=` parameter,
/// so the CLI and the server can never drift apart. Returns a plain
/// message on failure; callers wrap it in their own error type (CLI
/// error vs HTTP 400 body).
pub fn parse_rows(spec: &str) -> std::result::Result<std::ops::Range<usize>, String> {
    let (a, b) = spec
        .split_once("..")
        .ok_or_else(|| format!("rows '{spec}' is not of the form A..B"))?;
    let start: usize =
        a.trim().parse().map_err(|_| format!("bad row start '{a}'"))?;
    let end: usize = b.trim().parse().map_err(|_| format!("bad row end '{b}'"))?;
    Ok(start..end)
}

/// Run `f(i)` for every `i in 0..n` across up to `workers` scoped threads
/// pulling indices from a shared counter (work stealing) — the fan-out
/// shape shared by the reader's parallel decode and checksum-verify
/// paths. With one worker (or one item) `f` runs inline, thread-free.
/// Results are the closure's business (collect into a mutexed slot
/// vector, fold into an atomic, ...).
pub fn par_for_each<F: Fn(usize) + Sync>(n: usize, workers: usize, f: F) {
    if n == 0 {
        return;
    }
    let pool = workers.clamp(1, n);
    if pool == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..pool {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_for_each_visits_every_index_once() {
        for workers in [1usize, 3, 16] {
            let n = 97;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            par_for_each(n, workers, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "workers={workers}: every index exactly once"
            );
        }
        par_for_each(0, 4, |_| panic!("no items, no calls"));
    }

    #[test]
    fn parse_rows_grammar() {
        assert_eq!(parse_rows("3..9"), Ok(3..9));
        assert_eq!(parse_rows(" 0 .. 24 "), Ok(0..24));
        assert_eq!(parse_rows("9..7"), Ok(9..7), "inversion is the caller's check");
        assert!(parse_rows("abc").is_err());
        assert!(parse_rows("1..x").is_err());
        assert!(parse_rows("1-5").is_err());
        assert!(parse_rows("").is_err());
    }
}
