//! Small deterministic PRNG (no `rand` crate available offline).
//!
//! `Pcg32` is the PCG-XSH-RR 64/32 generator: tiny state, good statistical
//! quality, reproducible across platforms. Used by the dataset generators
//! and the property-testing helper.

/// PCG-XSH-RR 64/32 pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Create a generator from a seed with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_f64() * n as f64) as usize % n
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Poisson-distributed sample with mean `lambda` (Knuth for small, normal
    /// approximation for large lambda). Used by the APS detector-count model.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = lambda + lambda.sqrt() * self.normal();
            if x < 0.0 { 0 } else { x.round() as u64 }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..1000 {
            let x = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Pcg32::seeded(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(3);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Pcg32::seeded(5);
        let n = 5000;
        let m: f64 = (0..n).map(|_| r.poisson(4.0) as f64).sum::<f64>() / n as f64;
        assert!((m - 4.0).abs() < 0.2, "poisson mean {m}");
    }
}
