//! CRC-32 (IEEE 802.3, polynomial 0xEDB88320) — the per-chunk checksum of
//! the v2 container index (no external crc crate is available offline).
//!
//! The public API is unchanged since PR 2, but [`Crc32::update`] now folds
//! through the slice-by-8 kernel in [`crate::util::simd`] (eight bytes per
//! table step instead of one); the byte-at-a-time table below stays as the
//! reference the tests pin the kernel against. The incremental [`Crc32`]
//! form lets callers fold large payloads without materializing them
//! contiguously; [`crc32`] is the one-shot helper.

use std::sync::OnceLock;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// Incremental CRC-32 state.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    /// Fresh state (equivalent to hashing zero bytes).
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the state (slice-by-8 fast path).
    pub fn update(&mut self, bytes: &[u8]) {
        self.state = super::simd::crc32_update(self.state, bytes);
    }

    /// Fold `bytes` one table lookup per byte — the original PR 2 loop,
    /// kept as the reference implementation the fast path is tested
    /// against.
    pub fn update_reference(&mut self, bytes: &[u8]) {
        let t = table();
        let mut c = self.state;
        for &b in bytes {
            c = t[((c ^ u32::from(b)) & 0xff) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// Final checksum.
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // canonical check values for CRC-32/ISO-HDLC
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut inc = Crc32::new();
        for chunk in data.chunks(97) {
            inc.update(chunk);
        }
        assert_eq!(inc.finish(), crc32(&data));
    }

    #[test]
    fn fast_path_matches_reference_loop() {
        let mut rng = crate::util::rng::Pcg32::seeded(0xc4c);
        for _ in 0..50 {
            let n = rng.below(3000);
            let data = crate::util::prop::vec_u8(&mut rng, n);
            let mut fast = Crc32::new();
            let mut slow = Crc32::new();
            // uneven chunking exercises every slice-by-8 remainder path
            for chunk in data.chunks(rng.below(64) + 1) {
                fast.update(chunk);
                slow.update_reference(chunk);
            }
            assert_eq!(fast.finish(), slow.finish(), "n={n}");
        }
    }

    #[test]
    fn single_bit_flip_changes_checksum() {
        let mut data = vec![0u8; 4096];
        let base = crc32(&data);
        for i in [0usize, 1, 2048, 4095] {
            data[i] ^= 0x10;
            assert_ne!(crc32(&data), base, "flip at {i} undetected");
            data[i] ^= 0x10;
        }
    }
}
