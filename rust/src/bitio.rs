//! Bit-level I/O substrate.
//!
//! MSB-first bit writer/reader used by the Huffman encoders, the arithmetic
//! coder and the bitplane (unpred-aware) quantizer. Writes accumulate into a
//! `Vec<u8>`; reads borrow a byte slice.

use crate::error::{Result, SzError};

/// MSB-first bit writer with a 64-bit accumulator (word-wise `put_bits`
/// instead of bit-serial — the encoder hot path).
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits accumulated in the low end of `acc`, always < 8 after a flush.
    nbits: u32,
    acc: u64,
}

impl BitWriter {
    /// New empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Writer with pre-allocated capacity (bytes).
    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bytes), nbits: 0, acc: 0 }
    }

    #[inline]
    fn flush_bytes(&mut self) {
        while self.nbits >= 8 {
            self.nbits -= 8;
            self.buf.push((self.acc >> self.nbits) as u8);
        }
        self.acc &= (1u64 << self.nbits) - 1;
    }

    /// Write a single bit (LSB of `bit`).
    #[inline]
    pub fn put_bit(&mut self, bit: u32) {
        self.acc = (self.acc << 1) | (bit & 1) as u64;
        self.nbits += 1;
        if self.nbits == 8 {
            self.buf.push(self.acc as u8);
            self.acc = 0;
            self.nbits = 0;
        }
    }

    /// Write the low `n` bits of `value`, MSB first. `n` ≤ 64.
    #[inline]
    pub fn put_bits(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        let value = if n == 64 { value } else { value & ((1u64 << n) - 1) };
        if self.nbits + n <= 64 {
            self.acc = (self.acc << n) | value;
            self.nbits += n;
            self.flush_bytes();
        } else {
            // split: high part first (MSB-first order)
            let hi = n - (64 - self.nbits);
            self.put_bits(value >> hi, 64 - self.nbits);
            self.put_bits(value & ((1u64 << hi) - 1), hi);
        }
    }

    /// Number of complete bytes written so far (excluding partial byte).
    pub fn byte_len(&self) -> usize {
        self.buf.len()
    }

    /// Total bits written.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Flush pending bits (zero-padded) and return the byte buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            let pad = 8 - self.nbits;
            self.buf.push((self.acc << pad) as u8);
        }
        self.buf
    }
}

/// MSB-first bit reader over a byte slice.
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Next bit index (0 = MSB of buf[0]).
    pos: usize,
}

impl<'a> BitReader<'a> {
    /// Reader over `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Total bits available.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8
    }

    /// Bits consumed so far.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    /// Read one bit.
    #[inline]
    pub fn get_bit(&mut self) -> Result<u32> {
        let byte = self.pos >> 3;
        let Some(&b) = self.buf.get(byte) else {
            return Err(SzError::corrupt("bit stream exhausted"));
        };
        let bit = (b >> (7 - (self.pos & 7))) & 1;
        self.pos += 1;
        Ok(bit as u32)
    }

    /// Read one bit without an exhaustion check (returns 0 past the end).
    /// The arithmetic decoder relies on an implicit infinite zero tail.
    #[inline]
    pub fn get_bit_or_zero(&mut self) -> u32 {
        let byte = self.pos >> 3;
        let Some(&b) = self.buf.get(byte) else {
            self.pos += 1;
            return 0;
        };
        let bit = (b >> (7 - (self.pos & 7))) & 1;
        self.pos += 1;
        bit as u32
    }

    /// Read `n` bits (MSB first) as a u64.
    #[inline]
    pub fn get_bits(&mut self, n: u32) -> Result<u64> {
        debug_assert!(n <= 64);
        match self.pos.checked_add(n as usize) {
            Some(end) if end <= self.bit_len() => Ok(self.get_bits_unchecked(n)),
            _ => Err(SzError::corrupt("bit stream exhausted")),
        }
    }

    /// Read `n` ≤ 57 bits without an exhaustion check (zero-padded past the
    /// end). Word-wise fast path used by the LUT Huffman decoder.
    #[inline]
    pub fn get_bits_unchecked(&mut self, n: u32) -> u64 {
        let v = self.peek_bits(n);
        self.pos += n as usize;
        v
    }

    /// Peek `n` ≤ 57 bits at the cursor (MSB first), zero-padded past the
    /// end of the buffer.
    #[inline]
    pub fn peek_bits(&self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        let byte = self.pos >> 3;
        let bit = (self.pos & 7) as u32;
        let mut word = 0u64;
        // load up to 8 bytes starting at `byte`
        let tail = self.buf.get(byte..).unwrap_or(&[]);
        for (i, &b) in tail.iter().take(8).enumerate() {
            word |= (b as u64) << (56 - 8 * i);
        }
        (word << bit) >> (64 - n as u64)
    }

    /// Advance the cursor by `n` bits (after a successful `peek_bits`).
    #[inline]
    pub fn skip_bits(&mut self, n: u32) {
        self.pos += n as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Pcg32};

    #[test]
    fn roundtrip_bits() {
        let mut w = BitWriter::new();
        w.put_bits(0b1011, 4);
        w.put_bits(0xdead, 16);
        w.put_bit(1);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bits(4).unwrap(), 0b1011);
        assert_eq!(r.get_bits(16).unwrap(), 0xdead);
        assert_eq!(r.get_bit().unwrap(), 1);
    }

    #[test]
    fn empty_reader_errors() {
        let mut r = BitReader::new(&[]);
        assert!(r.get_bit().is_err());
        assert_eq!(r.get_bit_or_zero(), 0);
    }

    #[test]
    fn bit_len_tracks() {
        let mut w = BitWriter::new();
        for i in 0..13 {
            w.put_bit(i & 1);
        }
        assert_eq!(w.bit_len(), 13);
        assert_eq!(w.finish().len(), 2);
    }

    #[test]
    fn prop_roundtrip_random_bitstrings() {
        prop::cases(200, 0xb17, |rng| {
            let n = rng.below(500) + 1;
            let items: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let bits = rng.below(33) as u32 + 1;
                    let v = rng.next_u64() & ((1u64 << bits) - 1).max(1);
                    (v & if bits == 64 { u64::MAX } else { (1 << bits) - 1 }, bits)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, b) in &items {
                w.put_bits(v, b);
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &(v, b) in &items {
                assert_eq!(r.get_bits(b).unwrap(), v);
            }
        });
    }

    #[test]
    fn prop_single_bits() {
        prop::cases(50, 0xb18, |rng| {
            let bits: Vec<u32> = (0..rng.below(100) + 1).map(|_| rng.next_u32() & 1).collect();
            let mut w = BitWriter::new();
            for &b in &bits {
                w.put_bit(b);
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &b in &bits {
                assert_eq!(r.get_bit().unwrap(), b);
            }
        });
    }

    #[allow(unused)]
    fn _use_pcg(_: Pcg32) {}
}
