//! `sz3` — leader binary: compress/decompress files, stream synthetic
//! datasets through the coordinator, inspect streams, and run the
//! paper-figure harness subcommands.

use anyhow::{anyhow, bail, Context, Result};
use std::io::Write;
use std::path::Path;
use std::sync::Arc;
use sz3::cli::Args;
use sz3::config::JobConfig;
use sz3::coordinator::Coordinator;
use sz3::data::{Field, FieldValues};
use sz3::pipeline::{self, CompressConf, ErrorBound, PastriCompressor};
use sz3::runtime::{PjrtAnalyzer, PjrtEngine, PjrtService};

const USAGE: &str = "\
sz3 — modular prediction-based error-bounded lossy compression (SZ3 reproduction)

USAGE:
  sz3 compress   --input raw.bin --dims 100,500,500 --dtype f32
                 [--pipeline sz3-lr] [--abs EB | --rel EB | --pwrel EB]
                 [--radius N] --out file.sz3
  sz3 decompress --input file.sz3 --out raw.bin
  sz3 info       --input file.sz3
  sz3 serve      [--config job.json] [--dataset nyx|all] [--out dir]
  sz3 datasets                              # Table 3 registry
  sz3 pipelines                             # registry names
  sz3 quant-hist [--field ff|ff] [--eb 1e-10] [--radius 64]   # Fig. 3
  sz3 version

Raw input files are flat little-endian arrays of --dtype covering --dims.";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_bound(a: &Args) -> Result<ErrorBound> {
    if let Some(v) = a.get("abs") {
        return Ok(ErrorBound::Abs(v.parse()?));
    }
    if let Some(v) = a.get("rel") {
        return Ok(ErrorBound::Rel(v.parse()?));
    }
    if let Some(v) = a.get("pwrel") {
        return Ok(ErrorBound::PwRel(v.parse()?));
    }
    Ok(ErrorBound::Rel(1e-3))
}

fn read_raw_field(path: &str, dims: &[usize], dtype: &str, name: &str) -> Result<Field> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    let n: usize = dims.iter().product();
    let values = match dtype {
        "f32" => {
            if bytes.len() != n * 4 {
                bail!("{path}: expected {} bytes for f32 {:?}, found {}", n * 4, dims, bytes.len());
            }
            FieldValues::F32(
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            )
        }
        "f64" => {
            if bytes.len() != n * 8 {
                bail!("{path}: expected {} bytes for f64 {:?}, found {}", n * 8, dims, bytes.len());
            }
            FieldValues::F64(
                bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect(),
            )
        }
        "i32" => {
            if bytes.len() != n * 4 {
                bail!("{path}: expected {} bytes for i32 {:?}, found {}", n * 4, dims, bytes.len());
            }
            FieldValues::I32(
                bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            )
        }
        other => bail!("unsupported --dtype {other}"),
    };
    Ok(Field::new(name, dims, values)?)
}

fn write_raw_field(path: &str, field: &Field) -> Result<()> {
    let mut out = Vec::with_capacity(field.nbytes());
    match &field.values {
        FieldValues::F32(v) => v.iter().for_each(|x| out.extend_from_slice(&x.to_le_bytes())),
        FieldValues::F64(v) => v.iter().for_each(|x| out.extend_from_slice(&x.to_le_bytes())),
        FieldValues::I32(v) => v.iter().for_each(|x| out.extend_from_slice(&x.to_le_bytes())),
    }
    std::fs::write(path, out).with_context(|| format!("writing {path}"))?;
    Ok(())
}

fn run(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv)?;
    match a.subcommand.as_str() {
        "compress" => cmd_compress(&a),
        "decompress" => cmd_decompress(&a),
        "info" => cmd_info(&a),
        "serve" => cmd_serve(&a),
        "datasets" => cmd_datasets(),
        "pipelines" => cmd_pipelines(),
        "quant-hist" => cmd_quant_hist(&a),
        "version" => {
            println!("sz3 {}", sz3::version());
            Ok(())
        }
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand '{other}'\n\n{USAGE}")),
    }
}

fn cmd_compress(a: &Args) -> Result<()> {
    let dims = a.dims("dims")?;
    let dtype = a.get("dtype").unwrap_or("f32");
    let input = a.need("input")?;
    let out = a.need("out")?;
    let pipeline_name = a.get("pipeline").unwrap_or("sz3-lr");
    let stem = Path::new(input).file_stem().and_then(|s| s.to_str()).unwrap_or("field");
    let field = read_raw_field(input, &dims, dtype, stem)?;
    let conf = CompressConf::with_radius(parse_bound(a)?, a.get_or("radius", 32768u32)?);
    let c = pipeline::by_name(pipeline_name)
        .ok_or_else(|| anyhow!("unknown pipeline '{pipeline_name}' (see `sz3 pipelines`)"))?;
    let t0 = std::time::Instant::now();
    let stream = c.compress(&field, &conf)?;
    let dt = t0.elapsed();
    std::fs::write(out, &stream)?;
    let ratio = field.nbytes() as f64 / stream.len() as f64;
    println!(
        "{}: {} -> {} bytes (ratio {:.2}) in {:.2?} ({:.1} MB/s)",
        pipeline_name,
        field.nbytes(),
        stream.len(),
        ratio,
        dt,
        field.nbytes() as f64 / 1e6 / dt.as_secs_f64()
    );
    Ok(())
}

fn cmd_decompress(a: &Args) -> Result<()> {
    let input = a.need("input")?;
    let out = a.need("out")?;
    let stream = std::fs::read(input)?;
    let t0 = std::time::Instant::now();
    let field = pipeline::decompress_any(&stream)?;
    let dt = t0.elapsed();
    write_raw_field(out, &field)?;
    println!(
        "{}: {:?} {} -> {} bytes in {:.2?} ({:.1} MB/s)",
        field.name,
        field.shape.dims(),
        stream.len(),
        field.nbytes(),
        dt,
        field.nbytes() as f64 / 1e6 / dt.as_secs_f64()
    );
    Ok(())
}

fn cmd_info(a: &Args) -> Result<()> {
    let stream = std::fs::read(a.need("input")?)?;
    let h = pipeline::peek_header(&stream)?;
    println!(
        "pipeline={} field={} dtype={} dims={:?} elems={} stream_bytes={}",
        h.pipeline,
        h.field_name,
        h.dtype,
        h.dims,
        h.len(),
        stream.len()
    );
    Ok(())
}

fn cmd_serve(a: &Args) -> Result<()> {
    let cfg = match a.get("config") {
        Some(path) => JobConfig::from_json(&std::fs::read_to_string(path)?)?,
        None => JobConfig::default(),
    };
    let dataset = a.get("dataset").unwrap_or("nyx");
    let seed = a.get_or("seed", 42u64)?;
    let sets = sz3::datagen::survey(seed);
    let selected: Vec<_> = if dataset == "all" {
        sets
    } else {
        sets.into_iter().filter(|d| d.name == dataset).collect()
    };
    if selected.is_empty() {
        bail!("unknown dataset '{dataset}' (see `sz3 datasets`)");
    }
    let mut coord = Coordinator::from_config(&cfg)?;
    // PJRT-backed analysis for the blockwise pipelines when requested.
    if cfg.use_pjrt && (cfg.pipeline == "sz3-lr" || cfg.pipeline == "sz3-lr-s") {
        let dir = PjrtEngine::default_dir();
        if PjrtEngine::available(&dir) {
            let service = PjrtService::start(&dir)?;
            eprintln!(
                "using PJRT analysis engine ({}, dims {:?})",
                service.platform, service.dims
            );
            let specialized = cfg.pipeline == "sz3-lr-s";
            coord.make_compressor = Arc::new(move || {
                let base = if specialized {
                    pipeline::BlockCompressor::sz3_lr_s()
                } else {
                    pipeline::BlockCompressor::sz3_lr()
                };
                Box::new(
                    base.with_analyzer(Arc::new(PjrtAnalyzer::new(service.clone()))),
                )
            });
        } else {
            eprintln!("use_pjrt requested but no artifacts at {dir:?}; native analysis");
        }
    }
    let out_dir = a.get("out").map(|s| s.to_string());
    if let Some(d) = &out_dir {
        std::fs::create_dir_all(d)?;
    }
    for ds in selected {
        println!("== dataset {} ({}) ==", ds.name, ds.domain);
        let mut sink_err = None;
        let report = coord.run(ds.fields, |chunk| {
            if let Some(dir) = &out_dir {
                let path = format!(
                    "{dir}/{}.{:04}.sz3",
                    chunk.field.replace(['|', '/'], "_"),
                    chunk.chunk_index
                );
                if let Err(e) = std::fs::write(&path, &chunk.stream) {
                    sink_err.get_or_insert(e);
                }
            }
        })?;
        if let Some(e) = sink_err {
            return Err(e.into());
        }
        println!("{report}");
    }
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!("{:<12} {:<18} {:>7} {:>16} {:>10}  notes", "name", "domain", "fields", "dims", "size");
    for ds in sz3::datagen::survey(42) {
        let dims = ds.fields[0].shape.dims().to_vec();
        println!(
            "{:<12} {:<18} {:>7} {:>16} {:>9.1}MB  {}",
            ds.name,
            ds.domain,
            ds.fields.len(),
            format!("{dims:?}"),
            ds.nbytes() as f64 / 1e6,
            &ds.notes[..ds.notes.len().min(48)]
        );
    }
    Ok(())
}

fn cmd_pipelines() -> Result<()> {
    for name in [
        "sz3-lr",
        "sz3-lr-s",
        "sz3-interp",
        "sz3-truncation",
        "sz3-pastri",
        "sz-pastri",
        "sz-pastri-zstd",
        "sz3-aps",
        "lorenzo-1d",
        "fpzip-like",
    ] {
        println!("{name}");
    }
    Ok(())
}

/// Fig. 3: quantization-integer histograms for the Pastri pipeline.
fn cmd_quant_hist(a: &Args) -> Result<()> {
    let field_name = a.get("field").unwrap_or("ff|ff");
    let eb = a.get_or("eb", 1e-10f64)?;
    let radius = a.get_or("radius", 64u32)?;
    let n = a.get_or("n", 200_000usize)?;
    let class = match field_name {
        "ff|ff" => sz3::datagen::gamess::EriClass::FfFf,
        "ff|dd" => sz3::datagen::gamess::EriClass::FfDd,
        "dd|dd" => sz3::datagen::gamess::EriClass::DdDd,
        other => bail!("unknown GAMESS field '{other}'"),
    };
    let field = sz3::datagen::gamess::eri_field(class, n, a.get_or("seed", 42u64)?);
    let conf = CompressConf::with_radius(ErrorBound::Abs(eb), radius);
    let c = PastriCompressor::sz3();
    let (_, streams) = c.compress_instrumented(&field, &conf)?;
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    for (label, idx) in ["data", "pattern", "scale"].iter().zip(streams.iter()) {
        let mut hist = vec![0u64; (2 * radius) as usize + 1];
        let top = hist.len() - 1;
        for &i in idx {
            hist[(i as usize).min(top)] += 1;
        }
        let unpred = hist[0];
        writeln!(
            out,
            "# {label}: {} indices, {} unpredictable ({:.1}%)",
            idx.len(),
            unpred,
            100.0 * unpred as f64 / idx.len().max(1) as f64
        )?;
        for (bin, &count) in hist.iter().enumerate().skip(1) {
            if count > 0 {
                writeln!(out, "hist,{label},{},{}", bin as i64 - radius as i64, count)?;
            }
        }
    }
    Ok(())
}
