//! `sz3` — leader binary: compress/decompress files and chunked
//! containers, stream synthetic datasets through the coordinator, inspect
//! streams, and run the paper-figure harness subcommands.

use std::io::Write;
use std::path::Path;
use std::sync::Arc;
use sz3::cli::Args;
use sz3::config::JobConfig;
use sz3::container;
use sz3::coordinator::Coordinator;
use sz3::data::{Field, FieldValues};
use sz3::pipeline::{self, CompressConf, ErrorBound, PastriCompressor};
use sz3::runtime::{PjrtAnalyzer, PjrtEngine, PjrtService};

/// CLI-level result (anyhow is unavailable offline; `SzError`, I/O and
/// parse errors all box into the common error object).
type CliResult<T = ()> = std::result::Result<T, Box<dyn std::error::Error>>;

fn err(msg: String) -> Box<dyn std::error::Error> {
    msg.into()
}

const USAGE: &str = "\
sz3 — modular prediction-based error-bounded lossy compression (SZ3 reproduction)

USAGE:
  sz3 compress   --input raw.bin --dims 100,500,500 --dtype f32
                 [--pipeline NAME|SPEC] [--abs EB | --rel EB | --pwrel EB]
                 [--radius N] [--container] [--adaptive] [--measured]
                 [--optimize ratio|speed|balanced]
                 [--candidates a,b,c] [--chunk-elems N] [--workers N]
                 [--stats] [--trace trace.json] --out file.sz3
  sz3 compress   --series t0.bin,t1.bin,t2.bin --dims 100,500,500
                 [--tags a,b,c] [--no-delta] [...compress flags]
                 --out series.sz3c
  sz3 decompress --input file.sz3 --out raw.bin [--workers N]
                 [--stats] [--trace trace.json]
  sz3 extract    --input file.sz3c --out raw.bin [--field NAME]
                 [--rows A..B] [--snapshot K] [--workers N]
                 [--cache-mb MB] [--prefetch-kb N]
                 [--stats] [--trace trace.json]
  sz3 info       --input file.sz3
  sz3 serve      [--config job.json] [--dataset nyx|all] [--out dir]
                 [--container] [--adaptive] [--measured]
                 [--optimize ratio|speed|balanced]
  sz3 serve-http --dir artifacts/ [--addr 127.0.0.1:8080] [--threads N]
                 [--cache-mb MB] [--workers N] [--no-verify]
                 [--read-only] [--max-ingests N] [--max-body-mb MB]
                 [--max-conns N] [--read-timeout-s S]
                 [--log-format text|json]
  sz3 audit      [--json] [--strict] [--root DIR]   # static analysis
  sz3 datasets                              # Table 3 registry
  sz3 pipelines                             # aliases + stage catalog
  sz3 quant-hist [--field ff|ff] [--eb 1e-10] [--radius 64]   # Fig. 3
  sz3 version

Raw input files are flat little-endian arrays of --dtype covering --dims.
--pipeline takes a registry alias (sz3-lr, sz3-interp, ...) or a composed
pipeline spec like 'block(lorenzo+regression)/linear@r512/huffman/lzhuf'
(quote it — parentheses are shell syntax); `sz3 pipelines` lists every
alias and stage, docs/PIPELINES.md specifies the grammar. --candidates
accepts the same names/specs.
--container packs coordinator chunks into one SZ3C artifact; --adaptive
picks the best-fit pipeline per chunk (recorded in the chunk index).
--measured scores the candidates by compressing a stratified chunk sample
through each one (measured bytes + timing) instead of the residual proxy;
--optimize sets the objective (default ratio; see docs/SELECTION.md).
Both imply --adaptive.
audit lexes rust/src and enforces the panic-freedom / checked-arithmetic
rules over the untrusted-byte trust map (docs/AUDIT.md): --strict exits
nonzero on any unsuppressed finding (the blocking CI mode), --json emits
machine-readable findings, --root overrides the repo root (defaults to
the build-time crate root, so a deployed binary audits its own sources).
--series packs N timesteps of the same field (one raw file each, same
dims/dtype) into one v3 container with a snapshot table; snapshots after
the first are also compressed as residuals against the decoded previous
snapshot and each chunk keeps whichever stream is smaller (--no-delta
stores every chunk direct; --tags names the snapshots, defaulting to the
file stems).
extract seeks straight to the chunks overlapping --rows (half-open, along
the slowest axis) of snapshot --snapshot (default 0) and decodes only
those, CRC-checking each fetch on v2+ containers — the whole artifact is
never loaded. --cache-mb budgets the
decoded-chunk LRU in megabytes (0 disables; --cache is a deprecated
alias for --cache-mb and now also takes megabytes, not entries).
serve-http publishes every .sz3c under --dir over HTTP range queries
(list/meta/ROI/raw-chunk endpoints, /healthz, /statsz, /metricsz) with
one shared --cache-mb byte budget across all artifacts; see docs/SERVE.md.
The directory is writable over the API by default: `PUT /v1/artifacts/{id}`
compresses a raw body into a new artifact and publishes it atomically,
`DELETE` unpublishes, and `POST /v1/admin/rescan` reconciles with the
directory. --read-only disables all three; --max-ingests bounds
concurrent uploads (429 beyond it), --max-body-mb caps the request body
(413), --max-conns sheds connections at the accept edge (503), and
--read-timeout-s bounds a stalled request (408).
--stats prints a per-stage breakdown table (wall-time share, byte flow,
throughput) after the run; --trace FILE writes a Chrome trace_event JSON
of the run's spans — open it in Perfetto (ui.perfetto.dev) or
chrome://tracing. --log-format enables one access-log line per request on
stderr (docs/OBSERVABILITY.md covers the whole metrics/tracing surface).";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn parse_bound(a: &Args) -> CliResult<ErrorBound> {
    if let Some(v) = a.get("abs") {
        return Ok(ErrorBound::Abs(v.parse()?));
    }
    if let Some(v) = a.get("rel") {
        return Ok(ErrorBound::Rel(v.parse()?));
    }
    if let Some(v) = a.get("pwrel") {
        return Ok(ErrorBound::PwRel(v.parse()?));
    }
    Ok(ErrorBound::Rel(1e-3))
}

fn read_raw_field(path: &str, dims: &[usize], dtype: &str, name: &str) -> CliResult<Field> {
    let bytes =
        std::fs::read(path).map_err(|e| err(format!("reading {path}: {e}")))?;
    let n: usize = dims.iter().product();
    let expect = |size: usize| -> CliResult<()> {
        if bytes.len() != n * size {
            return Err(err(format!(
                "{path}: expected {} bytes for {dtype} {dims:?}, found {}",
                n * size,
                bytes.len()
            )));
        }
        Ok(())
    };
    let values = match dtype {
        "f32" => {
            expect(4)?;
            FieldValues::F32(
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            )
        }
        "f64" => {
            expect(8)?;
            FieldValues::F64(
                bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect(),
            )
        }
        "i32" => {
            expect(4)?;
            FieldValues::I32(
                bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            )
        }
        other => return Err(err(format!("unsupported --dtype {other}"))),
    };
    Ok(Field::new(name, dims, values)?)
}

fn write_raw_field(path: &str, field: &Field) -> CliResult {
    // the same flat little-endian layout the HTTP server's region
    // responses use, so `curl` output and `extract` output interchange
    std::fs::write(path, field.values.to_le_bytes())
        .map_err(|e| err(format!("writing {path}: {e}")))?;
    Ok(())
}

/// `--trace FILE` span sink: 2^18 events ≈ 16 MB ring, far beyond any
/// single CLI run; overflow drops the oldest and counts in
/// `sz3_trace_events_dropped_total`.
const TRACE_CAPACITY: usize = 1 << 18;

/// Arm the span tracer when `--trace FILE` was given; returns the path
/// the finished trace should be written to.
fn trace_setup(a: &Args) -> Option<String> {
    let path = a.get("trace")?.to_string();
    sz3::obs::trace::enable(TRACE_CAPACITY);
    Some(path)
}

/// Dump the collected spans as Chrome trace_event JSON (Perfetto /
/// chrome://tracing) and disarm the tracer.
fn trace_finish(path: Option<String>) -> CliResult {
    let Some(path) = path else { return Ok(()) };
    let json = sz3::obs::trace::dump_json().unwrap_or_else(|| "[]".to_string());
    sz3::obs::trace::disable();
    std::fs::write(&path, json).map_err(|e| err(format!("writing {path}: {e}")))?;
    eprintln!("trace written to {path} (open in Perfetto: ui.perfetto.dev)");
    Ok(())
}

/// `--stats` epilogue for compress-side commands.
fn print_compress_stats(wall: std::time::Duration) {
    print!("{}", sz3::obs::stage_table(&sz3::obs::COMPRESS_STAGES, wall));
}

/// `--stats` epilogue for decode-side commands (extract also appends the
/// reader fetch/CRC/decode breakdown).
fn print_decompress_stats(wall: std::time::Duration, with_reader: bool) {
    print!("{}", sz3::obs::stage_table(&sz3::obs::DECOMPRESS_STAGES, wall));
    if with_reader {
        print!("{}", sz3::obs::reader_table());
    }
}

fn run(argv: Vec<String>) -> CliResult {
    let a = Args::parse(argv)?;
    match a.subcommand.as_str() {
        "compress" => cmd_compress(&a),
        "decompress" => cmd_decompress(&a),
        "extract" => cmd_extract(&a),
        "info" => cmd_info(&a),
        "serve" => cmd_serve(&a),
        "serve-http" => cmd_serve_http(&a),
        "audit" => cmd_audit(&a),
        "datasets" => cmd_datasets(),
        "pipelines" => cmd_pipelines(),
        "quant-hist" => cmd_quant_hist(&a),
        "version" => {
            println!("sz3 {}", sz3::version());
            Ok(())
        }
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(err(format!("unknown subcommand '{other}'\n\n{USAGE}"))),
    }
}

/// Job config assembled from compress/serve flags (shared coordinator path).
fn job_config_from_flags(a: &Args, pipeline: &str, bound: ErrorBound) -> CliResult<JobConfig> {
    let mut cfg = JobConfig {
        pipeline: pipeline.to_string(),
        bound,
        ..Default::default()
    };
    cfg.radius = a.get_or("radius", cfg.radius)?;
    cfg.workers = a.get_or("workers", cfg.workers)?.max(1);
    cfg.chunk_elems = a.get_or("chunk-elems", cfg.chunk_elems)?;
    if cfg.chunk_elems < 1024 {
        // reject rather than silently clamp: the chunk count drives the
        // adaptive pipeline mix, so a quietly adjusted shard size would
        // produce a different artifact than the user asked for
        return Err(err(format!(
            "--chunk-elems {} below the 1024-element minimum",
            cfg.chunk_elems
        )));
    }
    cfg.queue_depth = a.get_or("queue-depth", cfg.queue_depth)?.max(1);
    cfg.adaptive = a.has("adaptive");
    if let Some(c) = a.list("candidates") {
        if c.is_empty() {
            return Err(err(
                "--candidates given but names no pipelines (e.g. --candidates sz3-lr,sz3-interp)"
                    .to_string(),
            ));
        }
        cfg.candidates = c;
        cfg.adaptive = true;
    }
    if a.has("measured") {
        cfg.measured = true;
        cfg.adaptive = true;
    }
    if let Some(t) = a.get("optimize") {
        // an objective only makes sense for measured scoring, so asking
        // for one opts into it
        cfg.optimize = t.to_string();
        cfg.measured = true;
        cfg.adaptive = true;
    }
    Ok(cfg)
}

/// `sz3 compress --series a.bin,b.bin,...`: pack N timesteps of one
/// field into a v3 series container, delta mode on unless --no-delta.
fn cmd_compress_series(a: &Args, series: Vec<String>) -> CliResult {
    let dims = a.dims("dims")?;
    let dtype = a.get("dtype").unwrap_or("f32");
    let out = a.need("out")?;
    if series.is_empty() {
        return Err(err("--series names no input files".to_string()));
    }
    let tags: Vec<String> = match a.list("tags") {
        Some(t) => {
            if t.len() != series.len() {
                return Err(err(format!(
                    "--tags names {} snapshots, --series has {}",
                    t.len(),
                    series.len()
                )));
            }
            t
        }
        None => series
            .iter()
            .map(|p| {
                Path::new(p)
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .unwrap_or("snapshot")
                    .to_string()
            })
            .collect(),
    };
    let stem = Path::new(&series[0])
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("field");
    let mut snapshots = Vec::with_capacity(series.len());
    let mut raw_bytes = 0usize;
    for (path, tag) in series.iter().zip(&tags) {
        // every snapshot carries the same field name — the series axis is
        // time, not identity
        let field = read_raw_field(path, &dims, dtype, stem)?;
        raw_bytes += field.nbytes();
        snapshots.push(sz3::coordinator::Snapshot::new(tag.clone(), vec![field]));
    }
    let pipeline_name = a.get("pipeline").unwrap_or("sz3-lr");
    let cfg = job_config_from_flags(a, pipeline_name, parse_bound(a)?)?;
    let coord = Coordinator::from_config(&cfg)?;
    let delta = !a.has("no-delta");
    let trace = trace_setup(a);
    let t0 = std::time::Instant::now();
    let (artifact, report) = coord.run_series_to_container(snapshots, delta)?;
    let dt = t0.elapsed();
    std::fs::write(out, &artifact)?;
    println!(
        "series[{}]: {report}",
        if delta { "delta" } else { "direct" }
    );
    println!(
        "{} -> {} bytes (ratio {:.2}) in {:.2?} ({:.1} MB/s)",
        raw_bytes,
        artifact.len(),
        raw_bytes as f64 / artifact.len() as f64,
        dt,
        raw_bytes as f64 / 1e6 / dt.as_secs_f64()
    );
    if a.has("stats") {
        print_compress_stats(dt);
    }
    trace_finish(trace)
}

fn cmd_compress(a: &Args) -> CliResult {
    if let Some(series) = a.list("series") {
        return cmd_compress_series(a, series);
    }
    let dims = a.dims("dims")?;
    let dtype = a.get("dtype").unwrap_or("f32");
    let input = a.need("input")?;
    let out = a.need("out")?;
    let pipeline_name = a.get("pipeline").unwrap_or("sz3-lr");
    let stem = Path::new(input).file_stem().and_then(|s| s.to_str()).unwrap_or("field");
    let field = read_raw_field(input, &dims, dtype, stem)?;
    let raw_bytes = field.nbytes();
    let bound = parse_bound(a)?;
    let trace = trace_setup(a);
    let t0 = std::time::Instant::now();
    let (stream, label) = if a.has("container")
        || a.has("adaptive")
        || a.has("measured")
        || a.get("optimize").is_some()
        || a.get("candidates").is_some()
    {
        // coordinator path: shard + (optionally) per-chunk best-fit
        // pipelines; the field moves in, so no second copy is held
        let cfg = job_config_from_flags(a, pipeline_name, bound)?;
        let coord = Coordinator::from_config(&cfg)?;
        let (artifact, report) = coord.run_to_container(vec![field])?;
        let label = if cfg.adaptive {
            let mix: Vec<String> = report
                .per_pipeline
                .iter()
                .map(|(p, n)| format!("{p}×{n}"))
                .collect();
            format!("container[{}]", mix.join(" "))
        } else {
            format!("container[{pipeline_name}×{}]", report.chunks)
        };
        (artifact, label)
    } else {
        let conf = CompressConf::with_radius(bound, a.get_or("radius", 32768u32)?);
        let c = pipeline::build(pipeline_name).map_err(|e| {
            err(format!(
                "pipeline '{pipeline_name}': {e} (see `sz3 pipelines` or \
                 docs/PIPELINES.md)"
            ))
        })?;
        (c.compress(&field, &conf)?, pipeline_name.to_string())
    };
    let dt = t0.elapsed();
    std::fs::write(out, &stream)?;
    let ratio = raw_bytes as f64 / stream.len() as f64;
    println!(
        "{}: {} -> {} bytes (ratio {:.2}) in {:.2?} ({:.1} MB/s)",
        label,
        raw_bytes,
        stream.len(),
        ratio,
        dt,
        raw_bytes as f64 / 1e6 / dt.as_secs_f64()
    );
    if a.has("stats") {
        print_compress_stats(dt);
    }
    trace_finish(trace)
}

fn cmd_decompress(a: &Args) -> CliResult {
    let input = a.need("input")?;
    let out = a.need("out")?;
    let stream = std::fs::read(input)?;
    let trace = trace_setup(a);
    let t0 = std::time::Instant::now();
    if container::is_container(&stream) {
        // symmetric with compress: --workers caps the decode fan-out too
        let workers = a.get_or("workers", sz3::util::default_workers())?.max(1);
        let fields = container::decompress_container(&stream, workers)?;
        let dt = t0.elapsed();
        let total: usize = fields.iter().map(Field::nbytes).sum();
        match fields.len() {
            1 => write_raw_field(out, &fields[0])?,
            _ => {
                // multi-field container: one raw file per field; sanitized
                // names that collide ("ff|dd" vs "ff/dd") get an index
                // suffix instead of silently overwriting each other
                let mut used = std::collections::HashSet::new();
                for (i, f) in fields.iter().enumerate() {
                    let safe = f.name.replace(['|', '/'], "_");
                    let path = if used.insert(safe.clone()) {
                        format!("{out}.{safe}")
                    } else {
                        format!("{out}.{safe}.{i}")
                    };
                    write_raw_field(&path, f)?;
                }
            }
        }
        println!(
            "container: {} fields, {} -> {} bytes in {:.2?} ({:.1} MB/s)",
            fields.len(),
            stream.len(),
            total,
            dt,
            total as f64 / 1e6 / dt.as_secs_f64()
        );
        if a.has("stats") {
            print_decompress_stats(dt, false);
        }
        return trace_finish(trace);
    }
    let field = pipeline::decompress_any(&stream)?;
    let dt = t0.elapsed();
    write_raw_field(out, &field)?;
    println!(
        "{}: {:?} {} -> {} bytes in {:.2?} ({:.1} MB/s)",
        field.name,
        field.shape.dims(),
        stream.len(),
        field.nbytes(),
        dt,
        field.nbytes() as f64 / 1e6 / dt.as_secs_f64()
    );
    if a.has("stats") {
        print_decompress_stats(dt, false);
    }
    trace_finish(trace)
}

/// Indexed-seek ROI extraction: open the container through a seekable file
/// source, decode only the chunks overlapping the requested rows, and
/// report exactly how little was fetched and decoded.
/// `--cache-mb` with the deprecated `--cache` alias: both are megabytes
/// of decoded-chunk cache budget now that the LRU accounts bytes (the
/// pre-byte-budget `--cache` counted entries).
fn cache_budget_bytes(a: &Args, default_mb: usize) -> CliResult<usize> {
    let mb = if a.get("cache-mb").is_some() {
        a.get_or("cache-mb", default_mb)?
    } else if a.get("cache").is_some() {
        eprintln!(
            "warning: --cache is deprecated (it used to count entries); \
             interpreting as --cache-mb (megabytes)"
        );
        a.get_or("cache", default_mb)?
    } else {
        default_mb
    };
    Ok(mb.saturating_mul(1 << 20))
}

fn cmd_extract(a: &Args) -> CliResult {
    let input = a.need("input")?;
    let out = a.need("out")?;
    let workers = a.get_or("workers", sz3::util::default_workers())?.max(1);
    let cache_bytes = cache_budget_bytes(a, 32)?;
    let prefetch_kb = a.get_or("prefetch-kb", 0usize)?;
    let source: Box<dyn sz3::reader::ChunkSource> = {
        let file = sz3::reader::FileSource::open(input)?;
        if prefetch_kb > 0 {
            Box::new(sz3::reader::PrefetchSource::new(Box::new(file), prefetch_kb * 1024))
        } else {
            Box::new(file)
        }
    };
    let reader = sz3::reader::ContainerReader::new(source)?
        .with_workers(workers)
        .with_cache_bytes(cache_bytes);
    let field = match a.get("field") {
        Some(f) => f.to_string(),
        None => {
            let names = reader.field_names();
            if names.len() == 1 {
                names[0].to_string()
            } else {
                return Err(err(format!(
                    "container holds {} fields ({:?}); pick one with --field",
                    names.len(),
                    names
                )));
            }
        }
    };
    let snapshot = a.get_or("snapshot", 0usize)?;
    let dims = reader.field_dims(&field)?.to_vec();
    let rows = match a.get("rows") {
        // the shared A..B grammar (sz3::util::parse_rows) — the HTTP
        // ROI endpoint parses the same spec with the same code
        Some(spec) => sz3::util::parse_rows(spec).map_err(|m| err(format!("--rows: {m}")))?,
        None => 0..dims[0],
    };
    let trace = trace_setup(a);
    let t0 = std::time::Instant::now();
    let region = reader.read_region_at(snapshot, &field, rows.clone())?;
    let dt = t0.elapsed();
    write_raw_field(out, &region)?;
    let s = reader.stats();
    let artifact_bytes = std::fs::metadata(input)?.len();
    // label the snapshot only on series artifacts, keeping the classic
    // single-snapshot output unchanged
    let snap_label = if reader.snapshot_count() > 1 {
        format!(" s{snapshot}")
    } else {
        String::new()
    };
    println!(
        "{field}{snap_label}[{}..{}] of {dims:?} (v{} via {}): decoded {} of {} chunks, \
         fetched {} of {} bytes, {} crc-checked, {} delta-resolved, \
         {} -> {} bytes in {:.2?} ({:.1} MB/s)",
        rows.start,
        rows.end,
        reader.version(),
        reader.source_kind(),
        s.chunks_decoded,
        reader.field_chunks(&field)?,
        s.bytes_fetched,
        artifact_bytes,
        s.crc_verified,
        s.delta_applied,
        s.bytes_fetched,
        region.nbytes(),
        dt,
        region.nbytes() as f64 / 1e6 / dt.as_secs_f64()
    );
    if a.has("stats") {
        print_decompress_stats(dt, true);
    }
    trace_finish(trace)
}

fn cmd_info(a: &Args) -> CliResult {
    let stream = std::fs::read(a.need("input")?)?;
    if container::is_container(&stream) {
        // formatting lives in the library so a test can lock the v1/v2
        // output byte-for-byte across format bumps (snapshot-aware for v3)
        let meta = container::read_index_meta(&stream)?;
        print!("{}", container::describe(&meta));
        return Ok(());
    }
    let h = pipeline::peek_header(&stream)?;
    println!(
        "pipeline={} field={} dtype={} dims={:?} elems={} stream_bytes={}",
        h.pipeline,
        h.field_name,
        h.dtype,
        h.dims,
        h.len(),
        stream.len()
    );
    Ok(())
}

fn cmd_serve(a: &Args) -> CliResult {
    let mut cfg = match a.get("config") {
        Some(path) => JobConfig::from_json(&std::fs::read_to_string(path)?)?,
        None => JobConfig::default(),
    };
    if a.has("adaptive") {
        cfg.adaptive = true;
    }
    if a.has("measured") {
        cfg.measured = true;
        cfg.adaptive = true;
    }
    if let Some(t) = a.get("optimize") {
        cfg.optimize = t.to_string();
        cfg.measured = true;
        cfg.adaptive = true;
    }
    let dataset = a.get("dataset").unwrap_or("nyx");
    let seed = a.get_or("seed", 42u64)?;
    let sets = sz3::datagen::survey(seed);
    let selected: Vec<_> = if dataset == "all" {
        sets
    } else {
        sets.into_iter().filter(|d| d.name == dataset).collect()
    };
    if selected.is_empty() {
        return Err(err(format!("unknown dataset '{dataset}' (see `sz3 datasets`)")));
    }
    let mut coord = Coordinator::from_config(&cfg)?;
    // PJRT-backed analysis when requested: in adaptive mode the worker pool
    // builds pipelines per chunk (make_compressor is bypassed), so PJRT
    // backs the *selector's* block analysis instead of the fixed
    // pipeline's — the log says which. The fixed path engages for any
    // block-family spec (sz3-lr/sz3-lr-s aliases included).
    let block_spec = pipeline::spec::resolve(&cfg.pipeline)
        .ok()
        .filter(|s| s.block_compressor().is_some());
    if cfg.use_pjrt && (cfg.adaptive || block_spec.is_some()) {
        let dir = PjrtEngine::default_dir();
        if PjrtEngine::available(&dir) {
            let service = PjrtService::start(&dir)?;
            if cfg.adaptive {
                eprintln!(
                    "using PJRT analysis engine for adaptive chunk selection ({}, dims {:?})",
                    service.platform, service.dims
                );
                // rebuild the selector from_config installed, keeping its
                // candidate set (single source of truth) but routing block
                // analysis through PJRT
                let base = coord.selector.take().expect("adaptive config sets a selector");
                let mut sel = container::AdaptiveChunkSelector::from_names(
                    base.candidates().iter().cloned(),
                )?;
                if cfg.measured {
                    // the rebuild must not silently drop measured scoring
                    sel = sel.with_measured(container::OptimizeTarget::from_name(
                        &cfg.optimize,
                    )?);
                }
                coord.selector = Some(Arc::new(
                    sel.with_analyzer(Arc::new(PjrtAnalyzer::new(service))),
                ));
            } else {
                eprintln!(
                    "using PJRT analysis engine ({}, dims {:?})",
                    service.platform, service.dims
                );
                let spec = block_spec.clone().expect("gated on a block-family spec");
                coord.make_compressor = Arc::new(move || {
                    Box::new(
                        spec.block_compressor()
                            .expect("block family")
                            .with_analyzer(Arc::new(PjrtAnalyzer::new(service.clone()))),
                    )
                });
            }
        } else {
            eprintln!("use_pjrt requested but no artifacts at {dir:?}; native analysis");
        }
    }
    let out_dir = a.get("out").map(|s| s.to_string());
    if let Some(d) = &out_dir {
        std::fs::create_dir_all(d)?;
    }
    let as_container = a.has("container");
    for ds in selected {
        println!("== dataset {} ({}) ==", ds.name, ds.domain);
        if as_container {
            // one self-describing SZ3C artifact per dataset, integrity-
            // checked through the random-access reader before publication
            let name = ds.name;
            let (artifact, report) = coord.run_to_container(ds.fields)?;
            let reader = sz3::reader::ContainerReader::from_slice(&artifact)?
                .with_workers(cfg.workers);
            let verified = reader.verify_checksums()?;
            if let Some(dir) = &out_dir {
                std::fs::write(format!("{dir}/{name}.sz3c"), &artifact)?;
            }
            println!("{report}");
            print!(
                "  index v{}: {} chunks, {} crc-verified",
                reader.version(),
                reader.index().entries.len(),
                verified
            );
            match &out_dir {
                Some(dir) => println!(
                    " (`sz3 extract --input {dir}/{name}.sz3c --field F \
                     --rows A..B --out roi.bin` for indexed-seek reads)"
                ),
                None => println!(),
            }
            continue;
        }
        let mut sink_err = None;
        let report = coord.run(ds.fields, |chunk| {
            if let Some(dir) = &out_dir {
                let path = format!(
                    "{dir}/{}.{:04}.sz3",
                    chunk.field.replace(['|', '/'], "_"),
                    chunk.chunk_index
                );
                if let Err(e) = std::fs::write(&path, &chunk.stream) {
                    sink_err.get_or_insert(e);
                }
            }
        })?;
        if let Some(e) = sink_err {
            return Err(e.into());
        }
        println!("{report}");
    }
    Ok(())
}

/// Serve a directory of `SZ3C` artifacts over HTTP range queries (see
/// `docs/SERVE.md` for the API contract). Writable by default (PUT /
/// DELETE / rescan against the same directory); `--read-only` pins the
/// startup set. Blocks until killed.
fn cmd_serve_http(a: &Args) -> CliResult {
    let dir = a.need("dir")?;
    let addr = a.get("addr").unwrap_or("127.0.0.1:8080");
    let threads = a.get_or("threads", 4usize)?.max(1);
    let log = match a.get("log-format") {
        None => sz3::server::LogFormat::None,
        Some("text") => sz3::server::LogFormat::Text,
        Some("json") => sz3::server::LogFormat::Json,
        Some(other) => {
            return Err(err(format!(
                "unknown --log-format '{other}' (expected text or json)"
            )))
        }
    };
    let opts = sz3::server::StoreOptions {
        cache_bytes: cache_budget_bytes(a, 256)?,
        workers: a.get_or("workers", sz3::util::default_workers())?.max(1),
        verify: !a.has("no-verify"),
    };
    let verify = opts.verify;
    let registry = if a.has("read-only") {
        let store = sz3::server::ArtifactStore::open_dir(dir, &opts)?;
        sz3::server::Registry::read_only(Arc::new(store))
    } else {
        sz3::server::Registry::open_dir(dir, &opts)?
            .with_max_inflight_ingests(a.get_or("max-ingests", 2usize)?.max(1))
    };
    for art in registry.snapshot().artifacts() {
        let fields: Vec<&str> =
            art.fields.iter().map(|f| f.name.as_str()).collect();
        println!(
            "artifact '{}': v{}, {} bytes, fields {:?}{}",
            art.id,
            art.reader.version(),
            art.file_bytes,
            fields,
            if verify { " (crc-verified)" } else { "" }
        );
    }
    let serve_opts = sz3::server::ServeOptions {
        threads,
        log,
        max_body: a
            .get_or("max-body-mb", 256usize)?
            .max(1)
            .saturating_mul(1 << 20),
        max_conns: a.get_or("max-conns", 256usize)?.max(1),
        read_timeout: std::time::Duration::from_secs(
            a.get_or("read-timeout-s", 5u64)?.max(1),
        ),
    };
    let writable = registry.writable();
    let handle = sz3::server::serve_registry(Arc::new(registry), addr, serve_opts)?;
    println!(
        "serving {} artifact(s) on http://{} ({} threads, cache budget {} MB, {})",
        handle.store().artifacts().len(),
        handle.addr(),
        threads,
        handle.store().cache().budget() >> 20,
        if writable { "writable" } else { "read-only" }
    );
    println!("try: curl http://{}/v1/artifacts", handle.addr());
    println!("metrics: curl http://{}/metricsz", handle.addr());
    handle.run_forever();
    Ok(())
}

/// `sz3 audit [--json] [--strict] [--root DIR]`: run the panic-freedom /
/// checked-arithmetic static analysis over `rust/src` (see docs/AUDIT.md).
/// `--strict` is the blocking CI mode: any unsuppressed finding fails.
fn cmd_audit(a: &Args) -> CliResult {
    let root = a
        .get("root")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    let report = sz3::analysis::audit_repo(&root)?;
    if a.has("json") {
        print!("{}", sz3::analysis::format_report_json(&report));
    } else {
        print!("{}", sz3::analysis::format_report(&report));
    }
    if a.has("strict") && !report.findings.is_empty() {
        return Err(err(format!(
            "audit --strict: {} unsuppressed finding(s)",
            report.findings.len()
        )));
    }
    Ok(())
}

fn cmd_datasets() -> CliResult {
    println!("{:<12} {:<18} {:>7} {:>16} {:>10}  notes", "name", "domain", "fields", "dims", "size");
    for ds in sz3::datagen::survey(42) {
        let dims = ds.fields[0].shape.dims().to_vec();
        println!(
            "{:<12} {:<18} {:>7} {:>16} {:>9.1}MB  {}",
            ds.name,
            ds.domain,
            ds.fields.len(),
            format!("{dims:?}"),
            ds.nbytes() as f64 / 1e6,
            &ds.notes[..ds.notes.len().min(48)]
        );
    }
    Ok(())
}

fn cmd_pipelines() -> CliResult {
    println!("aliases (each resolves to a canonical pipeline spec):");
    for (alias, canon) in sz3::pipeline::spec::ALIASES {
        println!("  {alias:<16} {canon}");
    }
    println!();
    println!(
        "stage catalog — compose any spec as \
         [preprocessor/]predictor/quantizer/encoder/lossless:"
    );
    let mut kind = "";
    for info in sz3::pipeline::spec::catalog() {
        if info.kind != kind {
            kind = info.kind;
            println!("  {kind}:");
        }
        if info.params.is_empty() {
            println!("    {:<28} {}", info.token, info.summary);
        } else {
            println!("    {:<28} {}  [{}]", info.token, info.summary, info.params);
        }
    }
    println!();
    println!("examples:");
    println!(
        "  sz3 compress ... --pipeline \
         'block(lorenzo+regression)/linear@r512/huffman/lzhuf'"
    );
    println!("  sz3 compress ... --pwrel 1e-3 --pipeline 'log/lorenzo/linear/arithmetic/bypass'");
    println!("grammar and composition recipes: docs/PIPELINES.md");
    Ok(())
}

/// Fig. 3: quantization-integer histograms for the Pastri pipeline.
fn cmd_quant_hist(a: &Args) -> CliResult {
    let field_name = a.get("field").unwrap_or("ff|ff");
    let eb = a.get_or("eb", 1e-10f64)?;
    let radius = a.get_or("radius", 64u32)?;
    let n = a.get_or("n", 200_000usize)?;
    let class = match field_name {
        "ff|ff" => sz3::datagen::gamess::EriClass::FfFf,
        "ff|dd" => sz3::datagen::gamess::EriClass::FfDd,
        "dd|dd" => sz3::datagen::gamess::EriClass::DdDd,
        other => return Err(err(format!("unknown GAMESS field '{other}'"))),
    };
    let field = sz3::datagen::gamess::eri_field(class, n, a.get_or("seed", 42u64)?);
    let conf = CompressConf::with_radius(ErrorBound::Abs(eb), radius);
    let c = PastriCompressor::sz3();
    let (_, streams) = c.compress_instrumented(&field, &conf)?;
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    for (label, idx) in ["data", "pattern", "scale"].iter().zip(streams.iter()) {
        let mut hist = vec![0u64; (2 * radius) as usize + 1];
        let top = hist.len() - 1;
        for &i in idx {
            hist[(i as usize).min(top)] += 1;
        }
        let unpred = hist[0];
        writeln!(
            out,
            "# {label}: {} indices, {} unpredictable ({:.1}%)",
            idx.len(),
            unpred,
            100.0 * unpred as f64 / idx.len().max(1) as f64
        )?;
        for (bin, &count) in hist.iter().enumerate().skip(1) {
            if count > 0 {
                writeln!(out, "hist,{label},{},{}", bin as i64 - radius as i64, count)?;
            }
        }
    }
    Ok(())
}
