//! Preprocessor stage (paper §3.2, Appendix A.1): transforms the input (and
//! the compression configuration) before prediction.
//!
//! Instances: [`Identity`] (bypass), [`LogTransform`] (pointwise-relative →
//! absolute error bounds, [20]), [`Transpose`] (APS layout change, §5.2) and
//! [`Linearize`] (treat N-d data as 1-d — also how unstructured grids enter
//! the framework, §1).

pub mod log_transform;
pub mod transpose;

pub use log_transform::LogTransform;
pub use transpose::Transpose;

use crate::byteio::{ByteReader, ByteWriter};
use crate::data::Field;
use crate::error::Result;
use crate::pipeline::CompressConf;

/// In-place data/conf transform applied before compression and reversed
/// after decompression. `process` returns serialized state which travels in
/// the stream and is handed back to `postprocess`.
pub trait Preprocessor: Send + Sync {
    /// Instance name for configs and stream headers.
    fn name(&self) -> &'static str;

    /// Transform `field` in place, possibly adjusting `conf` (e.g. a
    /// pointwise-relative bound becomes an absolute bound in log space).
    /// Returns opaque state bytes for `postprocess`.
    fn process(&self, field: &mut Field, conf: &mut CompressConf) -> Result<Vec<u8>>;

    /// Reverse the transform on the decompressed field.
    fn postprocess(&self, field: &mut Field, state: &[u8]) -> Result<()>;
}

/// No-op preprocessor (the paper's module bypass).
#[derive(Default, Clone)]
pub struct Identity;

impl Preprocessor for Identity {
    fn name(&self) -> &'static str {
        "identity"
    }
    fn process(&self, _field: &mut Field, _conf: &mut CompressConf) -> Result<Vec<u8>> {
        Ok(Vec::new())
    }
    fn postprocess(&self, _field: &mut Field, _state: &[u8]) -> Result<()> {
        Ok(())
    }
}

/// Reshape to 1-D (keeps the value order, drops dimensional structure).
/// The paper notes some 3-D datasets compress better treated as 1-D/2-D.
#[derive(Default, Clone)]
pub struct Linearize;

impl Preprocessor for Linearize {
    fn name(&self) -> &'static str {
        "linearize"
    }

    fn process(&self, field: &mut Field, _conf: &mut CompressConf) -> Result<Vec<u8>> {
        let mut w = ByteWriter::new();
        let dims = field.shape.dims().to_vec();
        w.put_varint(dims.len() as u64);
        for d in &dims {
            w.put_varint(*d as u64);
        }
        *field = Field::new(field.name.clone(), &[field.len()], field.values.clone())?;
        Ok(w.finish())
    }

    fn postprocess(&self, field: &mut Field, state: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(state);
        let nd = r.get_varint()? as usize;
        let mut dims = Vec::with_capacity(nd);
        for _ in 0..nd {
            dims.push(r.get_varint()? as usize);
        }
        *field = Field::new(field.name.clone(), &dims, field.values.clone())?;
        Ok(())
    }
}

/// Construct a boxed preprocessor by name (with default parameters).
pub fn by_name(name: &str) -> Option<Box<dyn Preprocessor>> {
    match name {
        "identity" => Some(Box::new(Identity)),
        "linearize" => Some(Box::new(Linearize)),
        "log" | "log_transform" => Some(Box::new(LogTransform::default())),
        "transpose" => None, // needs an explicit permutation
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::ErrorBound;

    #[test]
    fn linearize_roundtrip() {
        let mut f = Field::f32("x", &[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let orig = f.clone();
        let mut conf = CompressConf::new(ErrorBound::Abs(0.1));
        let st = Linearize.process(&mut f, &mut conf).unwrap();
        assert_eq!(f.shape.dims(), &[6]);
        Linearize.postprocess(&mut f, &st).unwrap();
        assert_eq!(f.shape.dims(), orig.shape.dims());
        assert_eq!(f.values, orig.values);
    }
}
