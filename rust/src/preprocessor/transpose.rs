//! Axis-permutation preprocessor (paper §5.2): the APS pipeline transposes
//! the `(time, y, x)` diffraction stack so the strongly-correlated time axis
//! becomes the fastest-varying one, turning the field into `y·x` contiguous
//! 1-D time series for the 1-D Lorenzo predictor.

use super::Preprocessor;
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::{Field, FieldValues, Shape};
use crate::error::{Result, SzError};
use crate::pipeline::CompressConf;

/// Permutes axes of a field. `perm[i]` gives the source axis for output
/// axis `i` (so `perm = [1, 2, 0]` moves axis 0 last).
#[derive(Clone, Debug)]
pub struct Transpose {
    /// Output-axis → source-axis mapping.
    pub perm: Vec<usize>,
}

impl Transpose {
    /// New transpose with the given permutation.
    pub fn new(perm: Vec<usize>) -> Self {
        Transpose { perm }
    }

    /// The APS permutation for 3-D stacks: time-first → time-last.
    pub fn time_last() -> Self {
        Transpose { perm: vec![1, 2, 0] }
    }

    fn validate(&self, nd: usize) -> Result<()> {
        let mut seen = vec![false; nd];
        if self.perm.len() != nd {
            return Err(SzError::Shape(format!(
                "perm {:?} does not match ndim {nd}",
                self.perm
            )));
        }
        for &p in &self.perm {
            if p >= nd || seen[p] {
                return Err(SzError::Shape(format!("invalid permutation {:?}", self.perm)));
            }
            seen[p] = true;
        }
        Ok(())
    }
}

fn permute_generic<T: Copy>(
    data: &[T],
    dims: &[usize],
    perm: &[usize],
) -> (Vec<T>, Vec<usize>) {
    let nd = dims.len();
    let src_shape = Shape::new(dims).expect("validated");
    let out_dims: Vec<usize> = perm.iter().map(|&p| dims[p]).collect();
    let out_shape = Shape::new(&out_dims).expect("validated");
    let mut out = Vec::with_capacity(data.len());
    let mut idx = vec![0usize; nd]; // output index
    let mut src_idx = vec![0usize; nd];
    for _ in 0..data.len() {
        for (o, &p) in perm.iter().enumerate() {
            src_idx[p] = idx[o];
        }
        out.push(data[src_shape.offset(&src_idx)]);
        out_shape.advance(&mut idx);
    }
    (out, out_dims)
}

fn apply_perm(field: &Field, perm: &[usize]) -> Result<Field> {
    let dims = field.shape.dims();
    let (values, out_dims) = match &field.values {
        FieldValues::F32(v) => {
            let (o, d) = permute_generic(v, dims, perm);
            (FieldValues::F32(o), d)
        }
        FieldValues::F64(v) => {
            let (o, d) = permute_generic(v, dims, perm);
            (FieldValues::F64(o), d)
        }
        FieldValues::I32(v) => {
            let (o, d) = permute_generic(v, dims, perm);
            (FieldValues::I32(o), d)
        }
    };
    Field::new(field.name.clone(), &out_dims, values)
}

impl Preprocessor for Transpose {
    fn name(&self) -> &'static str {
        "transpose"
    }

    fn process(&self, field: &mut Field, _conf: &mut CompressConf) -> Result<Vec<u8>> {
        self.validate(field.shape.ndim())?;
        *field = apply_perm(field, &self.perm)?;
        let mut w = ByteWriter::new();
        w.put_varint(self.perm.len() as u64);
        for &p in &self.perm {
            w.put_varint(p as u64);
        }
        Ok(w.finish())
    }

    fn postprocess(&self, field: &mut Field, state: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(state);
        let nd = r.get_varint()? as usize;
        let mut perm = Vec::with_capacity(nd);
        for _ in 0..nd {
            perm.push(r.get_varint()? as usize);
        }
        // inverse permutation
        let mut inv = vec![0usize; nd];
        for (o, &p) in perm.iter().enumerate() {
            inv[p] = o;
        }
        *field = apply_perm(field, &inv)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{CompressConf, ErrorBound};
    use crate::util::prop;

    #[test]
    fn transpose_2d() {
        let mut f = Field::f32("m", &[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let mut conf = CompressConf::new(ErrorBound::Abs(1.0));
        let t = Transpose::new(vec![1, 0]);
        let st = t.process(&mut f, &mut conf).unwrap();
        assert_eq!(f.shape.dims(), &[3, 2]);
        assert_eq!(f.values, FieldValues::F32(vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]));
        t.postprocess(&mut f, &st).unwrap();
        assert_eq!(f.values, FieldValues::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
    }

    #[test]
    fn time_last_roundtrip() {
        let vals: Vec<f32> = (0..24).map(|x| x as f32).collect();
        let mut f = Field::f32("aps", &[4, 2, 3], vals.clone()).unwrap();
        let orig = f.clone();
        let mut conf = CompressConf::new(ErrorBound::Abs(1.0));
        let t = Transpose::time_last();
        let st = t.process(&mut f, &mut conf).unwrap();
        assert_eq!(f.shape.dims(), &[2, 3, 4]);
        t.postprocess(&mut f, &st).unwrap();
        assert_eq!(f.values, orig.values);
        assert_eq!(f.shape.dims(), orig.shape.dims());
    }

    #[test]
    fn rejects_bad_perm() {
        let mut f = Field::f32("m", &[2, 2], vec![0.0; 4]).unwrap();
        let mut conf = CompressConf::new(ErrorBound::Abs(1.0));
        assert!(Transpose::new(vec![0, 0]).process(&mut f, &mut conf).is_err());
        assert!(Transpose::new(vec![0]).process(&mut f, &mut conf).is_err());
    }

    #[test]
    fn prop_roundtrip_random_perms() {
        prop::cases(40, 0x7a2, |rng| {
            let nd = rng.below(3) + 2;
            let dims: Vec<usize> = (0..nd).map(|_| rng.below(5) + 1).collect();
            let n: usize = dims.iter().product();
            let vals: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let mut f = Field::f32("p", &dims, vals.clone()).unwrap();
            let orig = f.clone();
            // random permutation via Fisher-Yates
            let mut perm: Vec<usize> = (0..nd).collect();
            for i in (1..nd).rev() {
                let j = rng.below(i + 1);
                perm.swap(i, j);
            }
            let t = Transpose::new(perm);
            let mut conf = CompressConf::new(ErrorBound::Abs(1.0));
            let st = t.process(&mut f, &mut conf).unwrap();
            t.postprocess(&mut f, &st).unwrap();
            assert_eq!(f.values, orig.values);
            assert_eq!(f.shape.dims(), orig.shape.dims());
        });
    }
}
