//! Logarithmic-transform preprocessor ([20], paper §3.2): converts a
//! pointwise-relative error bound into an absolute bound by moving data to
//! the log domain: if `|x'/x - 1| <= r` is required, compressing
//! `ln|x|` with absolute bound `ln(1 + r)` achieves it.
//!
//! Signs and exact zeros don't survive `ln|x|`, so they are recorded as
//! bitmaps in the preprocessor state and re-applied by `postprocess`.
//! Magnitudes below `zero_threshold` are treated as zeros (their relative
//! error is meaningless at denormal scale).

use super::Preprocessor;
use crate::bitio::{BitReader, BitWriter};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::{Field, FieldValues};
use crate::error::{Result, SzError};
use crate::pipeline::{CompressConf, ErrorBound};

/// Pointwise-relative → absolute bound preprocessor.
#[derive(Clone, Debug)]
pub struct LogTransform {
    /// Magnitudes below this are stored as exact zeros.
    pub zero_threshold: f64,
}

impl Default for LogTransform {
    fn default() -> Self {
        LogTransform { zero_threshold: 1e-300 }
    }
}

impl Preprocessor for LogTransform {
    fn name(&self) -> &'static str {
        "log_transform"
    }

    fn process(&self, field: &mut Field, conf: &mut CompressConf) -> Result<Vec<u8>> {
        let rel = match conf.bound {
            ErrorBound::PwRel(r) => r,
            _ => {
                return Err(SzError::config(
                    "log_transform requires a pointwise-relative bound",
                ))
            }
        };
        if rel <= 0.0 {
            return Err(SzError::config("relative bound must be positive"));
        }
        let mut signs = BitWriter::new();
        let mut zeros = BitWriter::new();
        let n = field.len();
        // placeholder for zeros in log domain: the min log value seen - 4eb
        let abs_eb = (1.0 + rel).ln();
        let mut transform = |vals: &mut Vec<f64>| {
            let mut min_log = f64::INFINITY;
            for v in vals.iter() {
                if v.abs() >= self.zero_threshold {
                    min_log = min_log.min(v.abs().ln());
                }
            }
            if !min_log.is_finite() {
                min_log = 0.0;
            }
            let fill = min_log - 4.0 * abs_eb;
            for v in vals.iter_mut() {
                let is_zero = v.abs() < self.zero_threshold;
                zeros.put_bit(is_zero as u32);
                signs.put_bit((*v < 0.0) as u32);
                *v = if is_zero { fill } else { v.abs().ln() };
            }
        };
        match &mut field.values {
            FieldValues::F64(v) => transform(v),
            FieldValues::F32(v) => {
                let mut tmp: Vec<f64> = v.iter().map(|&x| x as f64).collect();
                transform(&mut tmp);
                *v = tmp.iter().map(|&x| x as f32).collect();
            }
            FieldValues::I32(_) => {
                return Err(SzError::config("log_transform expects floating-point data"))
            }
        }
        conf.bound = ErrorBound::Abs(abs_eb);
        let mut w = ByteWriter::new();
        w.put_f64(rel);
        w.put_varint(n as u64);
        w.put_block(&signs.finish());
        w.put_block(&zeros.finish());
        Ok(w.finish())
    }

    fn postprocess(&self, field: &mut Field, state: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(state);
        let _rel = r.get_f64()?;
        let n = r.get_varint()? as usize;
        if n != field.len() {
            return Err(SzError::corrupt("log_transform: state/field length mismatch"));
        }
        let sign_bytes = r.get_block()?;
        let zero_bytes = r.get_block()?;
        let mut signs = BitReader::new(sign_bytes);
        let mut zeros = BitReader::new(zero_bytes);
        let mut untransform = |vals: &mut Vec<f64>| -> Result<()> {
            for v in vals.iter_mut() {
                let zero = zeros.get_bit()? == 1;
                let neg = signs.get_bit()? == 1;
                *v = if zero {
                    0.0
                } else {
                    let m = v.exp();
                    if neg {
                        -m
                    } else {
                        m
                    }
                };
            }
            Ok(())
        };
        match &mut field.values {
            FieldValues::F64(v) => untransform(v)?,
            FieldValues::F32(v) => {
                let mut tmp: Vec<f64> = v.iter().map(|&x| x as f64).collect();
                untransform(&mut tmp)?;
                *v = tmp.iter().map(|&x| x as f32).collect();
            }
            FieldValues::I32(_) => {
                return Err(SzError::config("log_transform expects floating-point data"))
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn relative_bound_becomes_absolute() {
        let mut f = Field::f64("x", &[4], vec![1.0, -2.0, 0.0, 1e5]).unwrap();
        let mut conf = CompressConf::new(ErrorBound::PwRel(0.01));
        let t = LogTransform::default();
        let st = t.process(&mut f, &mut conf).unwrap();
        match conf.bound {
            ErrorBound::Abs(eb) => assert!((eb - 1.01f64.ln()).abs() < 1e-12),
            _ => panic!("bound not converted"),
        }
        t.postprocess(&mut f, &st).unwrap();
        let vals = f.values.to_f64_vec();
        assert!((vals[0] - 1.0).abs() < 1e-9);
        assert!((vals[1] + 2.0).abs() < 1e-9);
        assert_eq!(vals[2], 0.0);
        assert!((vals[3] - 1e5).abs() < 1e-4);
    }

    #[test]
    fn prop_log_roundtrip_preserves_relative_bound() {
        // Full loop: transform, perturb log values within abs_eb (simulating
        // a compressor at the bound), untransform, check pointwise relative.
        prop::cases(40, 0x106, |rng| {
            let rel = 10f64.powf(rng.uniform(-4.0, -1.0));
            let n = rng.below(200) + 1;
            let vals: Vec<f64> = (0..n)
                .map(|_| {
                    if rng.below(10) == 0 {
                        0.0
                    } else {
                        let mag = 10f64.powf(rng.uniform(-5.0, 5.0));
                        if rng.below(2) == 0 {
                            -mag
                        } else {
                            mag
                        }
                    }
                })
                .collect();
            let mut f = Field::f64("x", &[n], vals.clone()).unwrap();
            let mut conf = CompressConf::new(ErrorBound::PwRel(rel));
            let t = LogTransform::default();
            let st = t.process(&mut f, &mut conf).unwrap();
            let abs_eb = match conf.bound {
                ErrorBound::Abs(e) => e,
                _ => unreachable!(),
            };
            // adversarial perturbation at the bound
            if let FieldValues::F64(v) = &mut f.values {
                for (i, x) in v.iter_mut().enumerate() {
                    *x += if i % 2 == 0 { abs_eb } else { -abs_eb };
                }
            }
            t.postprocess(&mut f, &st).unwrap();
            let out = f.values.to_f64_vec();
            for (o, d) in vals.iter().zip(out.iter()) {
                if *o == 0.0 {
                    assert_eq!(*d, 0.0);
                } else {
                    let r = (d / o - 1.0).abs();
                    assert!(r <= rel * (1.0 + 1e-9), "rel err {r} > {rel}");
                }
            }
        });
    }
}
