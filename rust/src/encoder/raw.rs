//! Raw (bypass) encoder: fixed-width bit packing with no entropy model.
//! Used by speed-first pipelines (paper §6.2, SZ3-Truncation bypasses
//! encoding entirely) and as a baseline in the encoder ablation bench.

use super::Encoder;
use crate::bitio::{BitReader, BitWriter};
use crate::byteio::{ByteReader, ByteWriter};
use crate::error::Result;

/// Fixed-width bit-packing codec.
#[derive(Default, Clone)]
pub struct RawEncoder;

impl RawEncoder {
    /// New instance.
    pub fn new() -> Self {
        RawEncoder
    }
}

impl Encoder for RawEncoder {
    fn name(&self) -> &'static str {
        "raw"
    }

    fn encode(&self, symbols: &[u32], w: &mut ByteWriter) -> Result<()> {
        let max = symbols.iter().copied().max().unwrap_or(0);
        let width = 32 - max.leading_zeros().min(31); // 1..=32, 0 if max==0
        let width = width.max(1);
        w.put_u8(width as u8);
        let mut bw = BitWriter::with_capacity(symbols.len() * width as usize / 8 + 1);
        for &s in symbols {
            bw.put_bits(s as u64, width);
        }
        w.put_block(&bw.finish());
        Ok(())
    }

    fn decode(&self, r: &mut ByteReader, n: usize) -> Result<Vec<u32>> {
        let width = r.get_u8()? as u32;
        let payload = r.get_block()?;
        let mut br = BitReader::new(payload);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(br.get_bits(width)? as u32);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoder::test_support::roundtrip;
    use crate::util::prop;

    #[test]
    fn roundtrip_edges() {
        let e = RawEncoder::new();
        roundtrip(&e, &[]);
        roundtrip(&e, &[0, 0, 0]);
        roundtrip(&e, &[u32::MAX, 0, 1]);
    }

    #[test]
    fn prop_roundtrip() {
        prop::cases(80, 0x7a3, |rng| {
            let n = rng.below(2000);
            let shift = rng.below(32) as u32;
            let syms: Vec<u32> = (0..n).map(|_| rng.next_u32() >> shift).collect();
            let e = RawEncoder::new();
            roundtrip(&e, &syms);
        });
    }
}
