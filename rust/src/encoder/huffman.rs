//! Canonical Huffman encoder (paper §3.2 "Huffman encoder").
//!
//! Builds a length-limited-free Huffman code from symbol frequencies,
//! converts it to canonical form, and serializes only the per-symbol code
//! lengths (RLE-compressed) — the decoder reconstructs identical codes.

use super::Encoder;
use crate::bitio::{BitReader, BitWriter};
use crate::byteio::{ByteReader, ByteWriter};
use crate::error::{Result, SzError};
use std::collections::BinaryHeap;
use std::cmp::Reverse;

/// Canonical Huffman codec.
#[derive(Default, Clone)]
pub struct HuffmanEncoder;

impl HuffmanEncoder {
    /// New encoder instance.
    pub fn new() -> Self {
        HuffmanEncoder
    }
}

/// Compute Huffman code lengths for `freqs` (0-frequency symbols get len 0).
pub fn code_lengths(freqs: &[u64]) -> Vec<u32> {
    let mut lens = vec![0u32; freqs.len()];
    let present: Vec<usize> = freqs
        .iter()
        .enumerate()
        .filter(|&(_, &f)| f > 0)
        .map(|(i, _)| i)
        .collect();
    match present.as_slice() {
        [] => return lens,
        [sym] => {
            if let Some(slot) = lens.get_mut(*sym) {
                *slot = 1;
            }
            return lens;
        }
        _ => {}
    }
    // Node arena: leaves then internals; parent links for length recovery.
    let n = present.len();
    let mut parent = vec![usize::MAX; 2 * n - 1];
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = present
        .iter()
        .enumerate()
        .map(|(node, &sym)| Reverse((freqs.get(sym).copied().unwrap_or(0), node)))
        .collect();
    let mut next = n;
    while heap.len() > 1 {
        let (Some(Reverse((fa, a))), Some(Reverse((fb, b)))) = (heap.pop(), heap.pop())
        else {
            break;
        };
        if let Some(slot) = parent.get_mut(a) {
            *slot = next;
        }
        if let Some(slot) = parent.get_mut(b) {
            *slot = next;
        }
        heap.push(Reverse((fa + fb, next)));
        next += 1;
    }
    for (node, &sym) in present.iter().enumerate() {
        let mut len = 0u32;
        let mut p = node;
        // parent links always point at later arena nodes, so this walk
        // strictly ascends and terminates at an unlinked root
        while let Some(&q) = parent.get(p) {
            if q == usize::MAX {
                break;
            }
            p = q;
            len += 1;
        }
        if let Some(slot) = lens.get_mut(sym) {
            *slot = len;
        }
    }
    lens
}

/// Assign canonical codes from lengths: symbols sorted by (len, symbol).
/// Returns (codes, max_len). Codes are stored in the low `len` bits.
/// Codes are u64: deep trees from very skewed priors can exceed 32 bits.
pub fn canonical_codes(lens: &[u32]) -> (Vec<u64>, u32) {
    let max_len = lens.iter().copied().max().unwrap_or(0);
    debug_assert!(max_len <= 64, "huffman depth {max_len} exceeds 64 bits");
    let depth = max_len.min(64) as usize;
    let mut count = vec![0u64; depth + 1];
    for &l in lens {
        if l > 0 {
            if let Some(slot) = count.get_mut(l as usize) {
                *slot += 1;
            }
        }
    }
    let mut first = vec![0u64; depth + 2];
    let mut code = 0u64;
    for l in 1..=depth {
        // lengths from `code_lengths` satisfy Kraft, so this never
        // saturates; saturating (instead of wrapping) keeps pathological
        // caller-supplied tables panic-free — decode paths detect them
        // via `CanonicalDecoder::from_lengths`, which errors instead
        let prev = count.get(l - 1).copied().unwrap_or(0);
        code = code
            .checked_add(prev)
            .and_then(|c| c.checked_shl(1))
            .unwrap_or(u64::MAX);
        if let Some(slot) = first.get_mut(l) {
            *slot = code;
        }
    }
    let mut next = first.clone();
    let mut codes = vec![0u64; lens.len()];
    for (sym, &l) in lens.iter().enumerate() {
        if l > 0 {
            if let (Some(nslot), Some(cslot)) =
                (next.get_mut(l as usize), codes.get_mut(sym))
            {
                *cslot = *nslot;
                *nslot += 1;
            }
        }
    }
    (codes, max_len)
}

/// Serialize code lengths: varint count then RLE pairs (len, run).
fn save_lengths(lens: &[u32], w: &mut ByteWriter) {
    w.put_varint(lens.len() as u64);
    let mut i = 0;
    while let Some(&l) = lens.get(i) {
        let mut run = 1usize;
        while lens.get(i + run) == Some(&l) {
            run += 1;
        }
        w.put_varint(l as u64);
        w.put_varint(run as u64);
        i += run;
    }
}

fn load_lengths(r: &mut ByteReader) -> Result<Vec<u32>> {
    let n = usize::try_from(r.get_varint()?)
        .map_err(|_| SzError::corrupt("huffman table too large"))?;
    if n > (1 << 28) {
        return Err(SzError::corrupt("huffman table too large"));
    }
    let mut lens = Vec::with_capacity(n);
    while lens.len() < n {
        let l = r.get_varint()?;
        let run = usize::try_from(r.get_varint()?)
            .map_err(|_| SzError::corrupt("bad huffman length RLE"))?;
        if lens.len() + run > n || l > 64 {
            return Err(SzError::corrupt("bad huffman length RLE"));
        }
        lens.extend(std::iter::repeat(l as u32).take(run));
    }
    Ok(lens)
}

/// Canonical Huffman decoder: a one-level lookup table resolves codes up
/// to [`LUT_BITS`] in a single peek (covers ~all symbols of peaked
/// quantization-index streams); longer codes fall back to the canonical
/// per-length scan.
pub struct CanonicalDecoder {
    max_len: u32,
    first_code: Vec<u64>,
    first_idx: Vec<u32>,
    symbols: Vec<u32>,
    count: Vec<u64>,
    /// `lut[prefix] = (symbol << 8) | code_len`, 0 = not in table.
    lut: Vec<u32>,
}

/// Width of the decode lookup table.
const LUT_BITS: u32 = 11;

impl CanonicalDecoder {
    /// Build decode tables from code lengths.
    pub fn from_lengths(lens: &[u32]) -> Result<Self> {
        let max_len = lens.iter().copied().max().unwrap_or(0);
        if max_len > 64 {
            return Err(SzError::corrupt("huffman depth exceeds 64 bits"));
        }
        let depth = max_len.min(64) as usize;
        let mut count = vec![0u64; depth + 1];
        for &l in lens {
            if l > 0 {
                if let Some(slot) = count.get_mut(l as usize) {
                    *slot += 1;
                }
            }
        }
        let mut first_code = vec![0u64; depth + 2];
        let mut first_idx = vec![0u32; depth + 2];
        let mut code = 0u64;
        let mut idx = 0u32;
        for l in 1..=depth {
            // hostile length tables (this is the decode side — the table
            // arrives from the stream) can push the canonical construction
            // past u64; overflow here is proof of corruption, not a wrap
            let prev = count.get(l - 1).copied().unwrap_or(0);
            code = code
                .checked_add(prev)
                .and_then(|c| c.checked_shl(1))
                .ok_or_else(|| SzError::corrupt("huffman code space overflows"))?;
            if let Some(slot) = first_code.get_mut(l) {
                *slot = code;
            }
            if let Some(slot) = first_idx.get_mut(l) {
                *slot = idx;
            }
            let here = count.get(l).copied().unwrap_or(0);
            idx = u32::try_from(here)
                .ok()
                .and_then(|c| idx.checked_add(c))
                .ok_or_else(|| SzError::corrupt("huffman table count overflows"))?;
        }
        // symbols in canonical order: sorted by (len, symbol)
        let len_of = |s: u32| lens.get(s as usize).copied().unwrap_or(0);
        let mut order: Vec<u32> = (0..lens.len() as u32).filter(|&s| len_of(s) > 0).collect();
        order.sort_by_key(|&s| (len_of(s), s));
        // build the fast table: every LUT_BITS prefix of a short code maps
        // to (symbol, len). `order` is sorted by (len, symbol), so symbols
        // of equal length are consecutive — one pass with a per-length
        // position counter replaces the old quadratic same-length rescan.
        let mut lut = vec![0u32; 1 << LUT_BITS];
        let mut run_len = 0u32;
        let mut idx_in_len = 0u64;
        for &sym in &order {
            let l = len_of(sym);
            if l != run_len {
                run_len = l;
                idx_in_len = 0;
            }
            let pos = idx_in_len;
            idx_in_len += 1;
            if l > LUT_BITS {
                continue;
            }
            // symbols ≥ 2^24 cannot pack into a `(sym << 8) | len` entry;
            // they stay decodable through the canonical-scan fallback
            if sym >= (1 << 24) {
                continue;
            }
            let code = first_code
                .get(l as usize)
                .copied()
                .unwrap_or(0)
                .checked_add(pos)
                .ok_or_else(|| SzError::corrupt("huffman code space overflows"))?;
            let shift = LUT_BITS - l;
            // an over-subscribed (non-Kraft) table can place `code` past the
            // prefix space; `skip` past the end simply yields no entries
            let base = (code << shift) as usize;
            let entry = (sym << 8) | l;
            for e in lut.iter_mut().skip(base).take(1 << shift) {
                *e = entry;
            }
        }
        Ok(CanonicalDecoder { max_len, first_code, first_idx, symbols: order, count, lut })
    }

    /// Decode one symbol (LUT fast path, canonical-scan fallback).
    #[inline]
    pub fn decode_one(&self, br: &mut BitReader) -> Result<u32> {
        let entry = self
            .lut
            .get(br.peek_bits(LUT_BITS) as usize)
            .copied()
            .unwrap_or(0);
        if entry != 0 {
            let len = entry & 0xff;
            br.skip_bits(len);
            if br.bit_pos() > br.bit_len() {
                return Err(SzError::corrupt("huffman stream exhausted"));
            }
            return Ok(entry >> 8);
        }
        let mut code = 0u64;
        let depth = self.count.len().saturating_sub(1);
        for l in 1..=depth {
            code = (code << 1) | br.get_bit()? as u64;
            let cnt = self.count.get(l).copied().unwrap_or(0);
            if cnt > 0 {
                let rel = code.wrapping_sub(self.first_code.get(l).copied().unwrap_or(0));
                if rel < cnt {
                    let at = self
                        .first_idx
                        .get(l)
                        .copied()
                        .unwrap_or(0)
                        .checked_add(rel as u32)
                        .ok_or_else(|| SzError::corrupt("invalid huffman code"))?;
                    return self
                        .symbols
                        .get(at as usize)
                        .copied()
                        .ok_or_else(|| SzError::corrupt("invalid huffman code"));
                }
            }
        }
        Err(SzError::corrupt("invalid huffman code"))
    }
}

impl Encoder for HuffmanEncoder {
    fn name(&self) -> &'static str {
        "huffman"
    }

    fn encode(&self, symbols: &[u32], w: &mut ByteWriter) -> Result<()> {
        if symbols.is_empty() {
            w.put_varint(0);
            return Ok(());
        }
        let max_sym = symbols.iter().copied().max().unwrap_or(0) as usize;
        let mut freqs = vec![0u64; max_sym + 1];
        for &s in symbols {
            if let Some(slot) = freqs.get_mut(s as usize) {
                *slot += 1;
            }
        }
        let lens = code_lengths(&freqs);
        let (codes, _) = canonical_codes(&lens);
        save_lengths(&lens, w);
        let mut bw = BitWriter::with_capacity(symbols.len() / 2);
        for &s in symbols {
            let (&code, &l) = codes
                .get(s as usize)
                .zip(lens.get(s as usize))
                .ok_or_else(|| SzError::Runtime("huffman code table misses a symbol".into()))?;
            bw.put_bits(code, l);
        }
        w.put_block(&bw.finish());
        Ok(())
    }

    fn decode(&self, r: &mut ByteReader, n: usize) -> Result<Vec<u32>> {
        if n == 0 {
            // the leading table-size varint is still present; consume it so
            // the cursor lands on the next section
            r.get_varint()?;
            return Ok(Vec::new());
        }
        // load_lengths reads the same leading varint written by save_lengths.
        let lens = load_lengths(r)?;
        let dec = CanonicalDecoder::from_lengths(&lens)?;
        let payload = r.get_block()?;
        // every canonical code is ≥ 1 bit, so a corrupt header demanding
        // more symbols than the payload has bits is rejected before the
        // output allocation is sized from it
        if n > payload.len().saturating_mul(8) {
            return Err(SzError::corrupt(format!(
                "{n} symbols exceed {}-byte huffman payload",
                payload.len()
            )));
        }
        let mut br = BitReader::new(payload);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(dec.decode_one(&mut br)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoder::test_support::{peaked_symbols, roundtrip};
    use crate::util::{prop, rng::Pcg32};

    #[test]
    fn empty_and_singleton() {
        let e = HuffmanEncoder::new();
        roundtrip(&e, &[]);
        roundtrip(&e, &[7]);
        roundtrip(&e, &[0, 0, 0, 0]);
    }

    #[test]
    fn skewed_stream_compresses() {
        let mut rng = Pcg32::seeded(2);
        let syms = peaked_symbols(&mut rng, 20000, 128, 3.0);
        let e = HuffmanEncoder::new();
        let size = roundtrip(&e, &syms);
        // ~20k symbols in ~10 distinct values: must beat 1 byte/symbol easily
        assert!(size < 20000, "huffman size {size}");
    }

    #[test]
    fn code_lengths_kraft_inequality() {
        prop::cases(100, 0x6bff, |rng| {
            let k = rng.below(300) + 2;
            let freqs: Vec<u64> = (0..k).map(|_| rng.below(1000) as u64).collect();
            let lens = code_lengths(&freqs);
            let kraft: f64 = lens
                .iter()
                .filter(|&&l| l > 0)
                .map(|&l| 2f64.powi(-(l as i32)))
                .sum();
            if lens.iter().filter(|&&l| l > 0).count() > 1 {
                assert!((kraft - 1.0).abs() < 1e-9, "kraft {kraft}");
            }
        });
    }

    #[test]
    fn prop_roundtrip_random_streams() {
        prop::cases(60, 0x4aff, |rng| {
            let n = rng.below(3000) + 1;
            let alpha = rng.below(500) + 1;
            let syms: Vec<u32> = (0..n).map(|_| rng.below(alpha) as u32).collect();
            let e = HuffmanEncoder::new();
            roundtrip(&e, &syms);
        });
    }

    #[test]
    fn near_entropy_on_uniform() {
        let mut rng = Pcg32::seeded(9);
        let syms: Vec<u32> = (0..1 << 14).map(|_| rng.below(256) as u32).collect();
        let e = HuffmanEncoder::new();
        let size = roundtrip(&e, &syms);
        // entropy = 8 bits/symbol; canonical huffman should be within 2%
        let bits_per_sym = size as f64 * 8.0 / syms.len() as f64;
        assert!(bits_per_sym < 8.4, "bits/sym {bits_per_sym}");
    }
}
