//! Canonical Huffman encoder (paper §3.2 "Huffman encoder").
//!
//! Builds a length-limited-free Huffman code from symbol frequencies,
//! converts it to canonical form, and serializes only the per-symbol code
//! lengths (RLE-compressed) — the decoder reconstructs identical codes.

use super::Encoder;
use crate::bitio::{BitReader, BitWriter};
use crate::byteio::{ByteReader, ByteWriter};
use crate::error::{Result, SzError};
use std::collections::BinaryHeap;
use std::cmp::Reverse;

/// Canonical Huffman codec.
#[derive(Default, Clone)]
pub struct HuffmanEncoder;

impl HuffmanEncoder {
    /// New encoder instance.
    pub fn new() -> Self {
        HuffmanEncoder
    }
}

/// Compute Huffman code lengths for `freqs` (0-frequency symbols get len 0).
pub fn code_lengths(freqs: &[u64]) -> Vec<u32> {
    let mut lens = vec![0u32; freqs.len()];
    let present: Vec<usize> = (0..freqs.len()).filter(|&i| freqs[i] > 0).collect();
    match present.len() {
        0 => return lens,
        1 => {
            lens[present[0]] = 1;
            return lens;
        }
        _ => {}
    }
    // Node arena: leaves then internals; parent links for length recovery.
    let n = present.len();
    let mut parent = vec![usize::MAX; 2 * n - 1];
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = present
        .iter()
        .enumerate()
        .map(|(node, &sym)| Reverse((freqs[sym], node)))
        .collect();
    let mut next = n;
    while heap.len() > 1 {
        let Reverse((fa, a)) = heap.pop().unwrap();
        let Reverse((fb, b)) = heap.pop().unwrap();
        parent[a] = next;
        parent[b] = next;
        heap.push(Reverse((fa + fb, next)));
        next += 1;
    }
    for (node, &sym) in present.iter().enumerate() {
        let mut len = 0u32;
        let mut p = node;
        while parent[p] != usize::MAX {
            p = parent[p];
            len += 1;
        }
        lens[sym] = len;
    }
    lens
}

/// Assign canonical codes from lengths: symbols sorted by (len, symbol).
/// Returns (codes, max_len). Codes are stored in the low `len` bits.
/// Codes are u64: deep trees from very skewed priors can exceed 32 bits.
pub fn canonical_codes(lens: &[u32]) -> (Vec<u64>, u32) {
    let max_len = lens.iter().copied().max().unwrap_or(0);
    debug_assert!(max_len <= 64, "huffman depth {max_len} exceeds 64 bits");
    let mut count = vec![0u64; max_len as usize + 1];
    for &l in lens {
        if l > 0 {
            count[l as usize] += 1;
        }
    }
    let mut first = vec![0u64; max_len as usize + 2];
    let mut code = 0u64;
    for l in 1..=max_len as usize {
        code = (code + count[l - 1]) << 1;
        first[l] = code;
    }
    let mut next = first.clone();
    let mut codes = vec![0u64; lens.len()];
    for (sym, &l) in lens.iter().enumerate() {
        if l > 0 {
            codes[sym] = next[l as usize];
            next[l as usize] += 1;
        }
    }
    (codes, max_len)
}

/// Serialize code lengths: varint count then RLE pairs (len, run).
fn save_lengths(lens: &[u32], w: &mut ByteWriter) {
    w.put_varint(lens.len() as u64);
    let mut i = 0;
    while i < lens.len() {
        let l = lens[i];
        let mut run = 1usize;
        while i + run < lens.len() && lens[i + run] == l {
            run += 1;
        }
        w.put_varint(l as u64);
        w.put_varint(run as u64);
        i += run;
    }
}

fn load_lengths(r: &mut ByteReader) -> Result<Vec<u32>> {
    let n = r.get_varint()? as usize;
    if n > (1 << 28) {
        return Err(SzError::corrupt("huffman table too large"));
    }
    let mut lens = Vec::with_capacity(n);
    while lens.len() < n {
        let l = r.get_varint()? as u32;
        let run = r.get_varint()? as usize;
        if lens.len() + run > n || l > 64 {
            return Err(SzError::corrupt("bad huffman length RLE"));
        }
        lens.extend(std::iter::repeat(l).take(run));
    }
    Ok(lens)
}

/// Canonical Huffman decoder: a one-level lookup table resolves codes up
/// to [`LUT_BITS`] in a single peek (covers ~all symbols of peaked
/// quantization-index streams); longer codes fall back to the canonical
/// per-length scan.
pub struct CanonicalDecoder {
    max_len: u32,
    first_code: Vec<u64>,
    first_idx: Vec<u32>,
    symbols: Vec<u32>,
    count: Vec<u64>,
    /// `lut[prefix] = (symbol << 8) | code_len`, 0 = not in table.
    lut: Vec<u32>,
}

/// Width of the decode lookup table.
const LUT_BITS: u32 = 11;

impl CanonicalDecoder {
    /// Build decode tables from code lengths.
    pub fn from_lengths(lens: &[u32]) -> Result<Self> {
        let max_len = lens.iter().copied().max().unwrap_or(0);
        if max_len > 64 {
            return Err(SzError::corrupt("huffman depth exceeds 64 bits"));
        }
        let mut count = vec![0u64; max_len as usize + 1];
        for &l in lens {
            if l > 0 {
                count[l as usize] += 1;
            }
        }
        let mut first_code = vec![0u64; max_len as usize + 2];
        let mut first_idx = vec![0u32; max_len as usize + 2];
        let mut code = 0u64;
        let mut idx = 0u32;
        for l in 1..=max_len as usize {
            code = (code + count[l - 1]) << 1;
            first_code[l] = code;
            first_idx[l] = idx;
            idx += count[l] as u32;
        }
        // symbols in canonical order: sorted by (len, symbol)
        let mut order: Vec<u32> = (0..lens.len() as u32).filter(|&s| lens[s as usize] > 0).collect();
        order.sort_by_key(|&s| (lens[s as usize], s));
        // build the fast table: every LUT_BITS prefix of a short code maps
        // to (symbol, len)
        let mut lut = vec![0u32; 1 << LUT_BITS];
        for &sym in &order {
            let l = lens[sym as usize];
            if l > LUT_BITS {
                continue;
            }
            // canonical code for sym
            let idx_in_len = {
                // position of sym among same-length symbols
                let mut i = 0u32;
                for &s2 in &order {
                    if lens[s2 as usize] == l {
                        if s2 == sym {
                            break;
                        }
                        i += 1;
                    }
                }
                i
            };
            let code = first_code[l as usize] + idx_in_len as u64;
            let shift = LUT_BITS - l;
            let base = (code << shift) as usize;
            let entry = (sym << 8) | l;
            for e in lut.iter_mut().skip(base).take(1 << shift) {
                *e = entry;
            }
        }
        Ok(CanonicalDecoder { max_len, first_code, first_idx, symbols: order, count, lut })
    }

    /// Decode one symbol (LUT fast path, canonical-scan fallback).
    #[inline]
    pub fn decode_one(&self, br: &mut BitReader) -> Result<u32> {
        let entry = self.lut[br.peek_bits(LUT_BITS) as usize];
        if entry != 0 {
            let len = entry & 0xff;
            br.skip_bits(len);
            if br.bit_pos() > br.bit_len() {
                return Err(SzError::corrupt("huffman stream exhausted"));
            }
            return Ok(entry >> 8);
        }
        let mut code = 0u64;
        for l in 1..=self.max_len as usize {
            code = (code << 1) | br.get_bit()? as u64;
            if self.count[l] > 0 {
                let offset = code.wrapping_sub(self.first_code[l]);
                if offset < self.count[l] {
                    return Ok(self.symbols[(self.first_idx[l] + offset as u32) as usize]);
                }
            }
        }
        Err(SzError::corrupt("invalid huffman code"))
    }
}

impl Encoder for HuffmanEncoder {
    fn name(&self) -> &'static str {
        "huffman"
    }

    fn encode(&self, symbols: &[u32], w: &mut ByteWriter) -> Result<()> {
        if symbols.is_empty() {
            w.put_varint(0);
            return Ok(());
        }
        let max_sym = *symbols.iter().max().unwrap() as usize;
        let mut freqs = vec![0u64; max_sym + 1];
        for &s in symbols {
            freqs[s as usize] += 1;
        }
        let lens = code_lengths(&freqs);
        let (codes, _) = canonical_codes(&lens);
        save_lengths(&lens, w);
        let mut bw = BitWriter::with_capacity(symbols.len() / 2);
        for &s in symbols {
            let l = lens[s as usize];
            bw.put_bits(codes[s as usize], l);
        }
        w.put_block(&bw.finish());
        Ok(())
    }

    fn decode(&self, r: &mut ByteReader, n: usize) -> Result<Vec<u32>> {
        if n == 0 {
            let _ = r.get_varint()?;
            return Ok(Vec::new());
        }
        // load_lengths reads the same leading varint written by save_lengths.
        let lens = load_lengths(r)?;
        let dec = CanonicalDecoder::from_lengths(&lens)?;
        let payload = r.get_block()?;
        // every canonical code is ≥ 1 bit, so a corrupt header demanding
        // more symbols than the payload has bits is rejected before the
        // output allocation is sized from it
        if n > payload.len().saturating_mul(8) {
            return Err(SzError::corrupt(format!(
                "{n} symbols exceed {}-byte huffman payload",
                payload.len()
            )));
        }
        let mut br = BitReader::new(payload);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(dec.decode_one(&mut br)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoder::test_support::{peaked_symbols, roundtrip};
    use crate::util::{prop, rng::Pcg32};

    #[test]
    fn empty_and_singleton() {
        let e = HuffmanEncoder::new();
        roundtrip(&e, &[]);
        roundtrip(&e, &[7]);
        roundtrip(&e, &[0, 0, 0, 0]);
    }

    #[test]
    fn skewed_stream_compresses() {
        let mut rng = Pcg32::seeded(2);
        let syms = peaked_symbols(&mut rng, 20000, 128, 3.0);
        let e = HuffmanEncoder::new();
        let size = roundtrip(&e, &syms);
        // ~20k symbols in ~10 distinct values: must beat 1 byte/symbol easily
        assert!(size < 20000, "huffman size {size}");
    }

    #[test]
    fn code_lengths_kraft_inequality() {
        prop::cases(100, 0x6bff, |rng| {
            let k = rng.below(300) + 2;
            let freqs: Vec<u64> = (0..k).map(|_| rng.below(1000) as u64).collect();
            let lens = code_lengths(&freqs);
            let kraft: f64 = lens
                .iter()
                .filter(|&&l| l > 0)
                .map(|&l| 2f64.powi(-(l as i32)))
                .sum();
            if lens.iter().filter(|&&l| l > 0).count() > 1 {
                assert!((kraft - 1.0).abs() < 1e-9, "kraft {kraft}");
            }
        });
    }

    #[test]
    fn prop_roundtrip_random_streams() {
        prop::cases(60, 0x4aff, |rng| {
            let n = rng.below(3000) + 1;
            let alpha = rng.below(500) + 1;
            let syms: Vec<u32> = (0..n).map(|_| rng.below(alpha) as u32).collect();
            let e = HuffmanEncoder::new();
            roundtrip(&e, &syms);
        });
    }

    #[test]
    fn near_entropy_on_uniform() {
        let mut rng = Pcg32::seeded(9);
        let syms: Vec<u32> = (0..1 << 14).map(|_| rng.below(256) as u32).collect();
        let e = HuffmanEncoder::new();
        let size = roundtrip(&e, &syms);
        // entropy = 8 bits/symbol; canonical huffman should be within 2%
        let bits_per_sym = size as f64 * 8.0 / syms.len() as f64;
        assert!(bits_per_sym < 8.4, "bits/sym {bits_per_sym}");
    }
}
