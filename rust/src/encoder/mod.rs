//! Encoder stage (paper §3.2, Appendix A.4): lossless entropy coding of the
//! quantization indices produced by the quantizer.
//!
//! Instances: canonical [`huffman::HuffmanEncoder`] (SZ default), the
//! [`fixed_huffman::FixedHuffmanEncoder`] with a predefined tree (SZ-Pastri,
//! APS pipeline), an adaptive [`arithmetic::ArithmeticEncoder`] (FPZIP-style)
//! and a [`raw::RawEncoder`] bypass.

pub mod arithmetic;
pub mod fixed_huffman;
pub mod huffman;
pub mod raw;

pub use arithmetic::ArithmeticEncoder;
pub use fixed_huffman::FixedHuffmanEncoder;
pub use huffman::HuffmanEncoder;
pub use raw::RawEncoder;

use crate::byteio::{ByteReader, ByteWriter};
use crate::error::Result;
use crate::obs;
use std::time::Instant;

/// Entropy coder over quantization indices.
///
/// `encode` writes both the codebook metadata (the paper's `save`) and the
/// coded payload into `w`; `decode` reads them back. An encoder must
/// round-trip any `&[u32]` exactly.
pub trait Encoder: Send + Sync {
    /// Instance name (for configs and stream headers).
    fn name(&self) -> &'static str;
    /// Encode `symbols` into `w` (metadata + payload).
    fn encode(&self, symbols: &[u32], w: &mut ByteWriter) -> Result<()>;
    /// Decode exactly `n` symbols from `r`.
    fn decode(&self, r: &mut ByteReader, n: usize) -> Result<Vec<u32>>;
}

/// Timing shim recording encode/decode stage metrics around any encoder.
/// Applied by [`by_name`], so every pipeline-built encoder reports into
/// [`crate::obs`] — one clock pair per chunk-level call, nothing per
/// symbol.
struct TimedEncoder {
    inner: Box<dyn Encoder>,
}

impl Encoder for TimedEncoder {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn encode(&self, symbols: &[u32], w: &mut ByteWriter) -> Result<()> {
        let start = Instant::now();
        let before = w.len();
        let out = self.inner.encode(symbols, w);
        let bytes_in = (symbols.len() as u64).saturating_mul(4);
        let bytes_out = w.len().saturating_sub(before) as u64;
        obs::stage(obs::ST_ENCODE).record(start, bytes_in, bytes_out);
        out
    }

    fn decode(&self, r: &mut ByteReader, n: usize) -> Result<Vec<u32>> {
        let start = Instant::now();
        let before = r.remaining();
        let out = self.inner.decode(r, n);
        let bytes_in = before.saturating_sub(r.remaining()) as u64;
        let bytes_out = match &out {
            Ok(v) => (v.len() as u64).saturating_mul(4),
            Err(_) => 0,
        };
        obs::stage(obs::ST_DECODE).record(start, bytes_in, bytes_out);
        out
    }
}

/// Construct a boxed encoder instance by name (wrapped in the
/// stage-metrics timing shim).
pub fn by_name(name: &str, radius: u32) -> Option<Box<dyn Encoder>> {
    let inner: Box<dyn Encoder> = match name {
        "huffman" => Box::new(HuffmanEncoder::new()),
        "fixed_huffman" => Box::new(FixedHuffmanEncoder::new(radius)),
        "arithmetic" => Box::new(ArithmeticEncoder::new()),
        "raw" => Box::new(RawEncoder::new()),
        _ => return None,
    };
    Some(Box::new(TimedEncoder { inner }))
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Round-trip `symbols` through `enc` and assert equality; returns the
    /// encoded size for ratio checks.
    pub fn roundtrip(enc: &dyn Encoder, symbols: &[u32]) -> usize {
        let mut w = ByteWriter::new();
        enc.encode(symbols, &mut w).expect("encode");
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        let back = enc.decode(&mut r, symbols.len()).expect("decode");
        assert_eq!(back, symbols, "encoder {} failed roundtrip", enc.name());
        buf.len()
    }

    /// Quantization-like symbol stream: peaked around `center`.
    pub fn peaked_symbols(rng: &mut Pcg32, n: usize, center: u32, spread: f64) -> Vec<u32> {
        (0..n)
            .map(|_| {
                let d = (rng.normal() * spread).round() as i64;
                (center as i64 + d).max(0) as u32
            })
            .collect()
    }
}
