//! Adaptive arithmetic (range) encoder — the FPZIP-style pipeline instance
//! (paper Fig. 1). Witten–Neal–Cleary style integer arithmetic coding with
//! an adaptive frequency model backed by a Fenwick tree, so alphabets as
//! large as the quantizer's full index range stay O(log K) per symbol.

use super::Encoder;
use crate::bitio::{BitReader, BitWriter};
use crate::byteio::{ByteReader, ByteWriter};
use crate::error::{Result, SzError};

const CODE_BITS: u32 = 32;
const TOP: u64 = 1 << CODE_BITS;
const HALF: u64 = TOP >> 1;
const QUARTER: u64 = TOP >> 2;
const THREE_QUARTER: u64 = HALF + QUARTER;
/// Rescale threshold for the adaptive model.
const MAX_TOTAL: u64 = 1 << 24;
/// Largest symbol alphabet a stream may declare (quantizer index ranges
/// are orders of magnitude smaller; anything bigger is a corrupt or
/// hostile length field, rejected before the model allocates).
const MAX_ALPHABET: usize = 1 << 28;

/// Fenwick (binary indexed) tree over symbol frequencies.
struct Fenwick {
    tree: Vec<u64>,
}

impl Fenwick {
    fn with_ones(n: usize) -> Self {
        // Initialize every frequency to 1 (uniform prior) in O(n).
        let mut tree = vec![0u64; n + 1];
        for i in 1..=n {
            let add = match tree.get_mut(i) {
                Some(slot) => {
                    *slot += 1;
                    *slot
                }
                None => continue,
            };
            let j = i + (i & i.wrapping_neg());
            if let Some(slot) = tree.get_mut(j) {
                *slot += add;
            }
        }
        Fenwick { tree }
    }

    fn len(&self) -> usize {
        self.tree.len() - 1
    }

    /// Sum of frequencies of symbols < sym.
    #[inline]
    fn cum(&self, sym: usize) -> u64 {
        let mut i = sym;
        let mut s = 0;
        while i > 0 {
            s += self.tree.get(i).copied().unwrap_or(0);
            i &= i - 1;
        }
        s
    }

    #[inline]
    fn add(&mut self, sym: usize, delta: i64) {
        let mut i = sym + 1;
        while let Some(slot) = self.tree.get_mut(i) {
            *slot = (*slot as i64 + delta) as u64;
            i += i & i.wrapping_neg();
        }
    }

    fn total(&self) -> u64 {
        self.cum(self.len())
    }

    /// Find the symbol whose cumulative interval contains `target`.
    #[inline]
    fn find(&self, target: u64) -> usize {
        let mut pos = 0usize;
        let mut rem = target;
        let mut mask = self.tree.len().next_power_of_two() >> 1;
        while mask > 0 {
            let next = pos.saturating_add(mask);
            if let Some(&t) = self.tree.get(next) {
                if t <= rem {
                    rem -= t;
                    pos = next;
                }
            }
            mask >>= 1;
        }
        pos
    }

    fn freq(&self, sym: usize) -> u64 {
        self.cum(sym + 1) - self.cum(sym)
    }

    /// Halve all frequencies (keeping ≥ 1) — adaptive-model rescale.
    fn rescale(&mut self) {
        let n = self.len();
        let freqs: Vec<u64> = (0..n).map(|s| (self.freq(s) + 1) / 2).collect();
        let mut tree = vec![0u64; n + 1];
        for (s, &f) in freqs.iter().enumerate() {
            let mut i = s + 1;
            // direct O(n log n) rebuild is fine: rescale is rare
            while let Some(slot) = tree.get_mut(i) {
                *slot += f;
                i += i & i.wrapping_neg();
            }
        }
        self.tree = tree;
    }
}

/// Adaptive arithmetic codec.
#[derive(Default, Clone)]
pub struct ArithmeticEncoder;

impl ArithmeticEncoder {
    /// New encoder instance.
    pub fn new() -> Self {
        ArithmeticEncoder
    }
}

struct RangeEncoder {
    low: u64,
    high: u64,
    pending: u64,
    bw: BitWriter,
}

impl RangeEncoder {
    fn new() -> Self {
        RangeEncoder { low: 0, high: TOP - 1, pending: 0, bw: BitWriter::new() }
    }

    #[inline]
    fn emit(&mut self, bit: u32) {
        self.bw.put_bit(bit);
        while self.pending > 0 {
            self.bw.put_bit(1 - bit);
            self.pending -= 1;
        }
    }

    #[inline]
    fn encode(&mut self, cum_lo: u64, cum_hi: u64, total: u64) {
        let range = self.high - self.low + 1;
        self.high = self.low + range * cum_hi / total - 1;
        self.low += range * cum_lo / total;
        loop {
            if self.high < HALF {
                self.emit(0);
            } else if self.low >= HALF {
                self.emit(1);
                self.low -= HALF;
                self.high -= HALF;
            } else if self.low >= QUARTER && self.high < THREE_QUARTER {
                self.pending += 1;
                self.low -= QUARTER;
                self.high -= QUARTER;
            } else {
                break;
            }
            self.low <<= 1;
            self.high = (self.high << 1) | 1;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        self.pending += 1;
        if self.low < QUARTER {
            self.emit(0);
        } else {
            self.emit(1);
        }
        self.bw.finish()
    }
}

struct RangeDecoder<'a> {
    low: u64,
    high: u64,
    code: u64,
    br: BitReader<'a>,
}

impl<'a> RangeDecoder<'a> {
    fn new(buf: &'a [u8]) -> Self {
        let mut br = BitReader::new(buf);
        let mut code = 0u64;
        for _ in 0..CODE_BITS {
            code = (code << 1) | br.get_bit_or_zero() as u64;
        }
        RangeDecoder { low: 0, high: TOP - 1, code, br }
    }

    #[inline]
    fn target(&self, total: u64) -> u64 {
        let range = self.high - self.low + 1;
        (((self.code - self.low + 1) * total - 1) / range).min(total - 1)
    }

    #[inline]
    fn consume(&mut self, cum_lo: u64, cum_hi: u64, total: u64) {
        let range = self.high - self.low + 1;
        self.high = self.low + range * cum_hi / total - 1;
        self.low += range * cum_lo / total;
        loop {
            if self.high < HALF {
                // nothing
            } else if self.low >= HALF {
                self.low -= HALF;
                self.high -= HALF;
                self.code -= HALF;
            } else if self.low >= QUARTER && self.high < THREE_QUARTER {
                self.low -= QUARTER;
                self.high -= QUARTER;
                self.code -= QUARTER;
            } else {
                break;
            }
            self.low <<= 1;
            self.high = (self.high << 1) | 1;
            self.code = (self.code << 1) | self.br.get_bit_or_zero() as u64;
        }
    }
}

impl Encoder for ArithmeticEncoder {
    fn name(&self) -> &'static str {
        "arithmetic"
    }

    fn encode(&self, symbols: &[u32], w: &mut ByteWriter) -> Result<()> {
        let alphabet = symbols.iter().copied().max().map(|m| m as usize + 1).unwrap_or(1);
        w.put_varint(alphabet as u64);
        if symbols.is_empty() {
            w.put_block(&[]);
            return Ok(());
        }
        let mut model = Fenwick::with_ones(alphabet);
        let mut enc = RangeEncoder::new();
        for &s in symbols {
            let s = s as usize;
            let lo = model.cum(s);
            let hi = lo + model.freq(s);
            let total = model.total();
            enc.encode(lo, hi, total);
            model.add(s, 32);
            if model.total() > MAX_TOTAL {
                model.rescale();
            }
        }
        w.put_block(&enc.finish());
        Ok(())
    }

    fn decode(&self, r: &mut ByteReader, n: usize) -> Result<Vec<u32>> {
        let alphabet = usize::try_from(r.get_varint()?)
            .map_err(|_| SzError::corrupt("arithmetic: alphabet exceeds usize"))?;
        let payload = r.get_block()?;
        if n == 0 {
            return Ok(Vec::new());
        }
        if alphabet == 0 {
            return Err(SzError::corrupt("arithmetic: empty alphabet"));
        }
        // the model allocates alphabet+1 u64s before any payload byte is
        // trusted — bound it so a 10-byte stream cannot demand gigabytes
        if alphabet > MAX_ALPHABET {
            return Err(SzError::corrupt(format!(
                "arithmetic: alphabet {alphabet} exceeds the {MAX_ALPHABET} cap"
            )));
        }
        let mut model = Fenwick::with_ones(alphabet);
        let mut dec = RangeDecoder::new(payload);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let total = model.total();
            let target = dec.target(total);
            let s = model.find(target);
            let lo = model.cum(s);
            let hi = lo + model.freq(s);
            dec.consume(lo, hi, total);
            out.push(s as u32);
            model.add(s, 32);
            if model.total() > MAX_TOTAL {
                model.rescale();
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoder::test_support::{peaked_symbols, roundtrip};
    use crate::encoder::HuffmanEncoder;
    use crate::util::{prop, rng::Pcg32};

    #[test]
    fn fenwick_ops() {
        let mut f = Fenwick::with_ones(10);
        assert_eq!(f.total(), 10);
        assert_eq!(f.cum(5), 5);
        f.add(3, 7);
        assert_eq!(f.freq(3), 8);
        assert_eq!(f.cum(4), 11);
        assert_eq!(f.find(3), 3);
        assert_eq!(f.find(4), 3); // inside symbol 3's widened interval
        assert_eq!(f.find(11), 4);
        f.rescale();
        assert_eq!(f.freq(3), 4);
        assert_eq!(f.freq(0), 1);
    }

    #[test]
    fn roundtrip_small() {
        let e = ArithmeticEncoder::new();
        roundtrip(&e, &[]);
        roundtrip(&e, &[0]);
        roundtrip(&e, &[5, 5, 5, 5, 5]);
        roundtrip(&e, &[0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn prop_roundtrip_random() {
        prop::cases(40, 0xa41, |rng| {
            let n = rng.below(1500) + 1;
            let alpha = rng.below(700) + 1;
            let syms: Vec<u32> = (0..n).map(|_| rng.below(alpha) as u32).collect();
            let e = ArithmeticEncoder::new();
            roundtrip(&e, &syms);
        });
    }

    #[test]
    fn beats_huffman_on_very_skewed_data() {
        // Arithmetic coding crosses the 1-bit/symbol floor that Huffman hits.
        let mut rng = Pcg32::seeded(6);
        let syms = peaked_symbols(&mut rng, 30000, 32, 0.3);
        let ar = ArithmeticEncoder::new();
        let hf = HuffmanEncoder::new();
        let sa = roundtrip(&ar, &syms);
        let sh = roundtrip(&hf, &syms);
        assert!(sa < sh, "arithmetic {sa} >= huffman {sh}");
    }
}
