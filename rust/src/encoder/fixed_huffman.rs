//! Fixed (predefined-tree) Huffman encoder — the SZ-Pastri variation
//! (paper §3.2): instead of building a tree from observed frequencies, both
//! sides derive the same canonical code from a parametric prior, eliminating
//! tree-construction time and table storage.
//!
//! The prior models quantization indices as a two-sided geometric
//! distribution centered on the quantizer's zero-error bin (`center`), which
//! is what linear-scaling quantization of a good predictor produces.

use super::huffman::{canonical_codes, code_lengths, CanonicalDecoder};
use super::Encoder;
use crate::bitio::{BitReader, BitWriter};
use crate::byteio::{ByteReader, ByteWriter};
use crate::error::{Result, SzError};

/// Largest alphabet a decoded stream header may declare; the derived
/// tables allocate proportionally, so hostile headers are bounded here.
const MAX_DECODE_ALPHABET: u32 = 1 << 24;

/// Huffman codec with a predefined geometric-prior tree.
#[derive(Clone)]
pub struct FixedHuffmanEncoder {
    center: u32,
    alphabet: u32,
    lens: Vec<u32>,
    codes: Vec<u64>,
}

impl FixedHuffmanEncoder {
    /// Build the fixed code for a quantizer with the given `radius`
    /// (alphabet = `2 * radius`, center bin = `radius`).
    pub fn new(radius: u32) -> Self {
        let radius = radius.max(1);
        Self::with_alphabet(radius, 2 * radius)
    }

    /// Build the fixed code with an explicit alphabet size.
    pub fn with_alphabet(center: u32, alphabet: u32) -> Self {
        let alphabet = alphabet.max(center + 1).max(2);
        // Two-sided geometric prior: freq(s) ∝ r^{|s-center|}, floor 1 so
        // every symbol is encodable; mass halves every 2 bins. The 2^24
        // scale caps the code depth at ~24 + log2(alphabet) < 64 bits.
        let mut freqs = vec![0u64; alphabet as usize];
        for (s, f) in freqs.iter_mut().enumerate() {
            let d = (s as i64 - center as i64).unsigned_abs();
            let shift = (d / 2).min(23) as u32;
            *f = (1u64 << 24) >> shift;
        }
        let lens = code_lengths(&freqs);
        let (codes, _) = canonical_codes(&lens);
        FixedHuffmanEncoder { center, alphabet, lens, codes }
    }
}

impl Encoder for FixedHuffmanEncoder {
    fn name(&self) -> &'static str {
        "fixed_huffman"
    }

    fn encode(&self, symbols: &[u32], w: &mut ByteWriter) -> Result<()> {
        // Only the parameters are stored — the tree is derived on load.
        w.put_varint(self.center as u64);
        w.put_varint(self.alphabet as u64);
        let mut bw = BitWriter::with_capacity(symbols.len() / 2);
        for &s in symbols {
            let (&code, &len) = self
                .codes
                .get(s as usize)
                .zip(self.lens.get(s as usize))
                .ok_or_else(|| {
                    SzError::config(format!(
                        "symbol {s} outside fixed alphabet {}",
                        self.alphabet
                    ))
                })?;
            bw.put_bits(code, len);
        }
        w.put_block(&bw.finish());
        Ok(())
    }

    fn decode(&self, r: &mut ByteReader, n: usize) -> Result<Vec<u32>> {
        let center = u32::try_from(r.get_varint()?)
            .map_err(|_| SzError::corrupt("fixed_huffman: center exceeds u32"))?;
        let alphabet = u32::try_from(r.get_varint()?)
            .map_err(|_| SzError::corrupt("fixed_huffman: alphabet exceeds u32"))?;
        // the derived table allocates `alphabet` slots before any payload
        // byte is trusted — bound it (real radii are orders of magnitude
        // smaller than this cap)
        if alphabet > MAX_DECODE_ALPHABET {
            return Err(SzError::corrupt(format!(
                "fixed_huffman: alphabet {alphabet} exceeds the \
                 {MAX_DECODE_ALPHABET} cap"
            )));
        }
        let table = if center == self.center && alphabet == self.alphabet {
            None // reuse our own tables
        } else {
            Some(FixedHuffmanEncoder::with_alphabet(center, alphabet))
        };
        let lens = table.as_ref().map(|t| &t.lens).unwrap_or(&self.lens);
        let dec = CanonicalDecoder::from_lengths(lens)?;
        let payload = r.get_block()?;
        // canonical codes are ≥ 1 bit each (see huffman.rs): bound the
        // requested symbol count by the payload bits before allocating
        if n > payload.len().saturating_mul(8) {
            return Err(SzError::corrupt(format!(
                "{n} symbols exceed {}-byte huffman payload",
                payload.len()
            )));
        }
        let mut br = BitReader::new(payload);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(dec.decode_one(&mut br)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoder::test_support::{peaked_symbols, roundtrip};
    use crate::encoder::HuffmanEncoder;
    use crate::util::{prop, rng::Pcg32};

    #[test]
    fn roundtrip_basic() {
        let e = FixedHuffmanEncoder::new(64);
        roundtrip(&e, &[64, 64, 63, 65, 0, 127, 64]);
        roundtrip(&e, &[]);
    }

    #[test]
    fn prop_roundtrip_within_alphabet() {
        prop::cases(60, 0xf1, |rng| {
            let radius = rng.below(200) as u32 + 2;
            let e = FixedHuffmanEncoder::new(radius);
            let n = rng.below(2000) + 1;
            let syms: Vec<u32> = (0..n).map(|_| rng.below(2 * radius as usize) as u32).collect();
            roundtrip(&e, &syms);
        });
    }

    #[test]
    fn rejects_out_of_alphabet() {
        let e = FixedHuffmanEncoder::new(4);
        let mut w = crate::byteio::ByteWriter::new();
        assert!(e.encode(&[100], &mut w).is_err());
    }

    #[test]
    fn close_to_adaptive_on_geometric_data() {
        // On data matching the prior, the fixed tree should be within ~15%
        // of the adaptive Huffman (which additionally pays table storage).
        let mut rng = Pcg32::seeded(4);
        let syms = peaked_symbols(&mut rng, 30000, 512, 4.0);
        let fixed = FixedHuffmanEncoder::new(512);
        let adaptive = HuffmanEncoder::new();
        let sf = roundtrip(&fixed, &syms);
        let sa = roundtrip(&adaptive, &syms);
        assert!((sf as f64) < sa as f64 * 1.25, "fixed {sf} vs adaptive {sa}");
    }
}
