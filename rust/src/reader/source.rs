//! Byte sources the [`super::ContainerReader`] fetches chunk payloads
//! through: a fully-resident slice, a seekable file (`Read + Seek`), and a
//! read-ahead wrapper for sequential scan patterns.
//!
//! The trait is deliberately positional (`read_at`) rather than streaming:
//! region reads jump straight to the chunks overlapping the request, and a
//! positional interface keeps the source stateless from the reader's point
//! of view, so concurrent decode workers can fetch independently.

use crate::error::{Result, SzError};
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Random-access byte source for container payload fetches.
pub trait ChunkSource: Send + Sync {
    /// Total artifact length in bytes.
    fn len(&self) -> u64;

    /// Fill `buf` from absolute byte `offset`; errors if the range is not
    /// fully available.
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()>;

    /// True for zero-length sources.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Diagnostic label ("slice", "file", "prefetch").
    fn kind(&self) -> &'static str;
}

/// In-memory artifact: the whole container is resident, `read_at` copies a
/// subrange. The zero-setup source behind
/// [`super::ContainerReader::from_slice`].
pub struct SliceSource<'a> {
    data: &'a [u8],
}

impl<'a> SliceSource<'a> {
    /// Source over a resident artifact.
    pub fn new(data: &'a [u8]) -> Self {
        SliceSource { data }
    }
}

impl ChunkSource for SliceSource<'_> {
    fn len(&self) -> u64 {
        self.data.len() as u64
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        let want = buf.len();
        let past_end = move || {
            SzError::corrupt(format!(
                "read [{offset}, +{want}) past end of {}-byte source",
                self.data.len()
            ))
        };
        let start = usize::try_from(offset).map_err(|_| past_end())?;
        let src = start
            .checked_add(want)
            .and_then(|end| self.data.get(start..end))
            .ok_or_else(past_end)?;
        buf.copy_from_slice(src);
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "slice"
    }
}

/// Seekable-stream artifact (`std::io::{Read, Seek}`): only the index and
/// the requested chunks are ever read, so a multi-GB container never has
/// to be resident. A `Mutex` serializes the seek+read pairs; decode work
/// dominates fetch time, so workers rarely contend.
pub struct FileSource<F> {
    inner: Mutex<F>,
    len: u64,
}

impl<F: Read + Seek + Send> FileSource<F> {
    /// Wrap a seekable stream (file, `Cursor`, ...); measures its length
    /// with one end-seek.
    pub fn new(mut stream: F) -> Result<Self> {
        let len = stream.seek(SeekFrom::End(0))?;
        Ok(FileSource { inner: Mutex::new(stream), len })
    }
}

impl FileSource<std::fs::File> {
    /// Open a container file for indexed-seek reads.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        Self::new(std::fs::File::open(path)?)
    }
}

impl<F: Read + Seek + Send> ChunkSource for FileSource<F> {
    fn len(&self) -> u64 {
        self.len
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        if offset
            .checked_add(buf.len() as u64)
            .map(|e| e > self.len)
            .unwrap_or(true)
        {
            return Err(SzError::corrupt(format!(
                "read [{offset}, +{}) past end of {}-byte source",
                buf.len(),
                self.len
            )));
        }
        let mut f = self
            .inner
            .lock()
            .map_err(|_| SzError::Runtime("file source lock poisoned".into()))?;
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)?;
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "file"
    }
}

/// Read-ahead wrapper: every miss fetches `window`-sized blocks from the
/// inner source, so sequential chunk walks (full-field reads, checksum
/// verification) issue one underlying read per window instead of one per
/// chunk. Random ROI probes simply miss through at no extra cost beyond
/// over-reading up to one window.
pub struct PrefetchSource<'a> {
    inner: Box<dyn ChunkSource + 'a>,
    window: usize,
    buffer: Mutex<Option<(u64, Vec<u8>)>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<'a> PrefetchSource<'a> {
    /// Default read-ahead window (1 MiB).
    pub const DEFAULT_WINDOW: usize = 1 << 20;

    /// Wrap `inner` with a read-ahead window of `window` bytes (min 4 KiB).
    pub fn new(inner: Box<dyn ChunkSource + 'a>, window: usize) -> Self {
        PrefetchSource {
            inner,
            window: window.max(4096),
            buffer: Mutex::new(None),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// (buffer hits, buffer misses) so far.
    pub fn hit_miss(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

impl ChunkSource for PrefetchSource<'_> {
    fn len(&self) -> u64 {
        self.inner.len()
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        let want = buf.len() as u64;
        let end = offset.checked_add(want).ok_or_else(|| {
            SzError::corrupt("prefetch read range overflows")
        })?;
        if end > self.inner.len() {
            return Err(SzError::corrupt(format!(
                "read [{offset}, +{want}) past end of {}-byte source",
                self.inner.len()
            )));
        }
        let mut guard = self
            .buffer
            .lock()
            .map_err(|_| SzError::Runtime("prefetch buffer lock poisoned".into()))?;
        if let Some((base, data)) = guard.as_ref() {
            if offset >= *base && end <= base + data.len() as u64 {
                let lo = (offset - base) as usize;
                if let Some(src) =
                    lo.checked_add(buf.len()).and_then(|hi| data.get(lo..hi))
                {
                    buf.copy_from_slice(src);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        // over-read a full window only when this miss extends a sequential
        // walk (or is the very first read); random probes — e.g. a
        // parallel ROI decode fetching chunks out of order — get exactly
        // what they asked for, so prefetch never multiplies their I/O
        let sequential = match guard.as_ref() {
            None => true,
            Some((base, data)) => offset == base + data.len() as u64,
        };
        let fetch = if sequential {
            (self.window as u64)
                .max(want)
                .min(self.inner.len() - offset) as usize
        } else {
            want as usize
        };
        let mut data = vec![0u8; fetch];
        self.inner.read_at(offset, &mut data)?;
        let src = data.get(..buf.len()).ok_or_else(|| {
            SzError::Runtime("prefetch fetched fewer bytes than requested".into())
        })?;
        buf.copy_from_slice(src);
        *guard = Some((offset, data));
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "prefetch"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn bytes(n: usize) -> Vec<u8> {
        (0..n).map(|i| (i * 7 % 251) as u8).collect()
    }

    #[test]
    fn slice_source_reads_and_bounds_checks() {
        let data = bytes(100);
        let s = SliceSource::new(&data);
        assert_eq!(s.len(), 100);
        let mut buf = [0u8; 10];
        s.read_at(5, &mut buf).unwrap();
        assert_eq!(&buf[..], &data[5..15]);
        assert!(s.read_at(95, &mut buf).is_err());
        assert!(s.read_at(u64::MAX - 3, &mut buf).is_err());
    }

    #[test]
    fn file_source_over_cursor_matches_slice() {
        let data = bytes(4096);
        let f = FileSource::new(Cursor::new(data.clone())).unwrap();
        assert_eq!(f.len(), 4096);
        let mut buf = [0u8; 64];
        f.read_at(1000, &mut buf).unwrap();
        assert_eq!(&buf[..], &data[1000..1064]);
        assert!(f.read_at(4090, &mut buf).is_err(), "past-end read must fail");
    }

    #[test]
    fn prefetch_turns_sequential_reads_into_window_fetches() {
        let data = bytes(64 * 1024);
        let p = PrefetchSource::new(Box::new(SliceSource::new(&data)), 16 * 1024);
        let mut buf = [0u8; 1024];
        for i in 0..32 {
            p.read_at(i * 1024, &mut buf).unwrap();
            assert_eq!(&buf[..], &data[(i as usize) * 1024..][..1024]);
        }
        let (hits, misses) = p.hit_miss();
        assert_eq!(hits + misses, 32);
        assert!(misses <= 3, "32 KiB walked in 16 KiB windows: misses {misses}");
        assert!(hits >= 28, "sequential walk should hit the window: hits {hits}");
    }

    #[test]
    fn prefetch_bounds_checked_before_fetch() {
        let data = bytes(1000);
        let p = PrefetchSource::new(Box::new(SliceSource::new(&data)), 1 << 20);
        let mut buf = [0u8; 100];
        // window larger than the source clamps instead of erroring
        p.read_at(950, &mut buf[..50]).unwrap();
        assert!(p.read_at(950, &mut buf).is_err());
    }
}
