//! Random-access container reader: indexed-seek reads over `SZ3C`
//! artifacts without materializing the whole container.
//!
//! [`ContainerReader`] parses only the chunk index (via
//! [`crate::container::read_index_meta`], which needs an index-covering
//! prefix, not the payload), then fetches chunk payloads on demand through
//! a [`ChunkSource`] — an in-memory slice, a seekable file, or a
//! prefetching wrapper. On top of that it offers:
//!
//! * **Region-of-interest extraction** — [`ContainerReader::read_region`]
//!   decodes only the chunks overlapping a row range (in parallel, the
//!   same scoped worker-pool pattern as the coordinator) and assembles
//!   exactly the requested sub-field.
//! * **Decoded-chunk LRU cache** — keyed by `(field, chunk_index)` and
//!   budgeted in **bytes** ([`ChunkCache`]), so repeated serve-path
//!   queries hit warm chunks instead of re-decoding. A cache can be
//!   private to one reader ([`ContainerReader::with_cache_bytes`]) or
//!   shared, scope-prefixed, across every artifact a server holds open
//!   ([`ContainerReader::with_shared_cache`] — the `sz3 serve-http`
//!   deployment shape, one `--cache-mb` knob for the whole process).
//! * **Integrity on every fetch** — v2 containers carry a CRC-32 per
//!   chunk, verified before any byte reaches a decoder; the inner `SZ3R`
//!   header's pipeline name is cross-checked against the index; decoded
//!   dims are verified against the declared row range.
//!
//! This is the *single* seek/verify/decode path:
//! [`crate::container::decompress_container`] and
//! [`crate::container::decompress_single_field`] are thin wrappers over
//! [`ContainerReader::read_all`].

pub mod cache;
pub mod source;

pub use cache::{ChunkCache, ChunkKey};
pub use source::{ChunkSource, FileSource, PrefetchSource, SliceSource};

use crate::container::{self, ChunkEntry, ContainerIndex};
use crate::coordinator::slice_rows;
use crate::data::{Field, FieldValues};
use crate::error::{Result, SzError};
use crate::pipeline;
use crate::util::crc32::crc32;
use std::ops::Range;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Initial prefix size tried when parsing the index from a source; doubled
/// until the index parses or the whole artifact has been read.
const INDEX_PREFIX_PROBE: usize = 1 << 14;

/// Monotonic counters describing what a reader actually did — the decode
/// counters the ROI tests assert on, and the serve path's observability.
#[derive(Default)]
struct Counters {
    chunks_fetched: AtomicU64,
    bytes_fetched: AtomicU64,
    crc_verified: AtomicU64,
    chunks_decoded: AtomicU64,
    cache_hits: AtomicU64,
}

/// Snapshot of a reader's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReadStats {
    /// Chunk payloads fetched from the source.
    pub chunks_fetched: u64,
    /// Payload bytes fetched from the source.
    pub bytes_fetched: u64,
    /// Chunks whose CRC-32 was checked (0 for v1 containers).
    pub crc_verified: u64,
    /// Chunks run through a decompression pipeline.
    pub chunks_decoded: u64,
    /// Decodes avoided by the warm-chunk cache.
    pub cache_hits: u64,
}

/// Per-field view assembled from the index at open time: entry ids sorted
/// by chunk position, with coverage already validated.
struct FieldMeta {
    name: String,
    dims: Vec<usize>,
    /// Indices into `index.entries`, sorted by `chunk_index`.
    entry_ids: Vec<usize>,
}

/// Indexed-seek reader over one `SZ3C` container.
pub struct ContainerReader<'a> {
    source: Box<dyn ChunkSource + 'a>,
    index: ContainerIndex,
    fields: Vec<FieldMeta>,
    version: u8,
    payload_offset: u64,
    payload_len: u64,
    workers: usize,
    cache: Arc<ChunkCache>,
    /// Prefix prepended to field names in cache keys so artifacts sharing
    /// one cache cannot collide (empty for a private cache).
    cache_scope: String,
    counters: Counters,
}

impl<'a> ContainerReader<'a> {
    /// Open a container through any [`ChunkSource`]: reads an
    /// index-covering prefix (growing geometrically — the payload is never
    /// touched), validates every entry, and verifies per-field coverage
    /// (complete, duplicate-free, contiguous rows) so later region reads
    /// can trust the index.
    pub fn new(source: Box<dyn ChunkSource + 'a>) -> Result<Self> {
        let total = source.len();
        // magic/version screen first: a non-container or unsupported
        // artifact is decidable from the first 5 bytes — don't walk a
        // multi-GB file with the growing-prefix loop below just to report
        // an error the header already proves
        let mut head = [0u8; 5];
        if total < head.len() as u64 {
            return Err(SzError::corrupt("container shorter than its header"));
        }
        source.read_at(0, &mut head)?;
        if &head[..4] != container::CONTAINER_MAGIC {
            return Err(SzError::corrupt("bad container magic"));
        }
        if head[4] != container::VERSION_V1 && head[4] != container::VERSION_V2 {
            return Err(SzError::corrupt(format!(
                "unsupported container version {}",
                head[4]
            )));
        }
        let mut prefix_len = (INDEX_PREFIX_PROBE as u64).min(total) as usize;
        let meta = loop {
            let mut prefix = vec![0u8; prefix_len];
            source.read_at(0, &mut prefix)?;
            match container::read_index_meta(&prefix) {
                Ok(meta) => break meta,
                // only buffer exhaustion means "the index is longer than
                // this prefix" — grow and retry; validation errors (bad
                // ranges, overflow, ...) are verdicts and fail fast
                // without walking the rest of a multi-GB artifact
                Err(e) if e.is_exhaustion() && (prefix_len as u64) < total => {
                    prefix_len = ((prefix_len as u64) * 2).min(total) as usize;
                }
                Err(e) => return Err(e),
            }
        };
        let payload_end = (meta.payload_offset as u64)
            .checked_add(meta.payload_len)
            .ok_or_else(|| SzError::corrupt("payload extent overflows"))?;
        if payload_end > total {
            return Err(SzError::corrupt(format!(
                "container truncated: payload ends at byte {payload_end}, \
                 source holds {total}"
            )));
        }
        let fields = validate_coverage(&meta.index)?;
        Ok(ContainerReader {
            source,
            index: meta.index,
            fields,
            version: meta.version,
            payload_offset: meta.payload_offset as u64,
            payload_len: meta.payload_len,
            workers: crate::util::default_workers(),
            cache: Arc::new(ChunkCache::new(0)),
            cache_scope: String::new(),
            counters: Counters::default(),
        })
    }

    /// Reader over a fully-resident artifact.
    pub fn from_slice(stream: &'a [u8]) -> Result<Self> {
        Self::new(Box::new(SliceSource::new(stream)))
    }

    /// Reader over a container file — only the index and requested chunks
    /// are ever read from disk.
    pub fn open_path(path: impl AsRef<Path>) -> Result<ContainerReader<'static>> {
        ContainerReader::new(Box::new(FileSource::open(path)?))
    }

    /// Cap the parallel-decode fan-out (defaults to the core count).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Enable a private decoded-chunk LRU cache with a budget of `bytes`
    /// (decoded payload bytes plus a small per-entry overhead; 0 disables).
    pub fn with_cache_bytes(mut self, bytes: usize) -> Self {
        self.cache = Arc::new(ChunkCache::new(bytes));
        self.cache_scope = String::new();
        self
    }

    /// Attach a cache shared with other readers, namespaced by `scope`
    /// (typically the artifact id) so identical field names in different
    /// artifacts occupy distinct entries. This is how `sz3 serve-http`
    /// puts every open artifact behind one process-wide `--cache-mb`
    /// budget.
    pub fn with_shared_cache(mut self, cache: Arc<ChunkCache>, scope: &str) -> Self {
        self.cache = cache;
        self.cache_scope = if scope.is_empty() {
            String::new()
        } else {
            // unit separator: cannot appear in a scope id derived from a
            // file stem, so "a" + field "b" never aliases scope "ab"
            format!("{scope}\u{1f}")
        };
        self
    }

    /// The decoded-chunk cache this reader charges against.
    pub fn cache(&self) -> &Arc<ChunkCache> {
        &self.cache
    }

    /// Container format version (1 or 2).
    pub fn version(&self) -> u8 {
        self.version
    }

    /// Total payload bytes (the concatenated compressed chunk streams,
    /// excluding the index).
    pub fn payload_bytes(&self) -> u64 {
        self.payload_len
    }

    /// The parsed chunk index.
    pub fn index(&self) -> &ContainerIndex {
        &self.index
    }

    /// Diagnostic label of the underlying source.
    pub fn source_kind(&self) -> &'static str {
        self.source.kind()
    }

    /// Field names in order of first appearance in the index.
    pub fn field_names(&self) -> Vec<&str> {
        self.fields.iter().map(|f| f.name.as_str()).collect()
    }

    /// Full dims of `field`.
    pub fn field_dims(&self, field: &str) -> Result<&[usize]> {
        Ok(&self.field_meta(field)?.dims)
    }

    /// Number of chunks `field` is sharded into.
    pub fn field_chunks(&self, field: &str) -> Result<usize> {
        Ok(self.field_meta(field)?.entry_ids.len())
    }

    /// Snapshot of the decode/fetch counters.
    pub fn stats(&self) -> ReadStats {
        ReadStats {
            chunks_fetched: self.counters.chunks_fetched.load(Ordering::Relaxed),
            bytes_fetched: self.counters.bytes_fetched.load(Ordering::Relaxed),
            crc_verified: self.counters.crc_verified.load(Ordering::Relaxed),
            chunks_decoded: self.counters.chunks_decoded.load(Ordering::Relaxed),
            cache_hits: self.counters.cache_hits.load(Ordering::Relaxed),
        }
    }

    fn field_meta(&self, field: &str) -> Result<&FieldMeta> {
        self.fields.iter().find(|f| f.name == field).ok_or_else(|| {
            SzError::config(format!(
                "container has no field '{field}' (holds {:?})",
                self.field_names()
            ))
        })
    }

    /// Fetch one chunk's payload bytes, CRC-verified when the index
    /// carries a checksum (v2).
    fn fetch_verified(&self, e: &ChunkEntry) -> Result<Vec<u8>> {
        let offset = self
            .payload_offset
            .checked_add(e.offset as u64)
            .ok_or_else(|| SzError::corrupt("chunk offset overflows"))?;
        let mut buf = vec![0u8; e.len];
        self.source.read_at(offset, &mut buf)?;
        self.counters.chunks_fetched.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_fetched.fetch_add(e.len as u64, Ordering::Relaxed);
        if let Some(expect) = e.crc32 {
            let got = crc32(&buf);
            if got != expect {
                return Err(SzError::corrupt(format!(
                    "chunk {} of '{}': crc32 mismatch (index {expect:#010x}, \
                     payload {got:#010x})",
                    e.chunk_index, e.field
                )));
            }
            self.counters.crc_verified.fetch_add(1, Ordering::Relaxed);
        }
        Ok(buf)
    }

    /// Decode one index entry: cache lookup, else fetch → verify →
    /// dispatch on the index pipeline (cross-checked against the inner
    /// stream header) → decode → dims check → cache insert.
    fn decode_entry(&self, id: usize) -> Result<Arc<Field>> {
        let e = &self.index.entries[id];
        // only pay the key's String build when a cache is actually on
        let key: Option<ChunkKey> = (self.cache.budget() > 0)
            .then(|| (format!("{}{}", self.cache_scope, e.field), e.chunk_index));
        if let Some(k) = &key {
            if let Some(hit) = self.cache.get(k) {
                self.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(hit);
            }
        }
        let stream = self.fetch_verified(e)?;
        let compressor = pipeline::by_name(&e.pipeline).ok_or_else(|| {
            SzError::corrupt(format!("unknown pipeline '{}' in chunk index", e.pipeline))
        })?;
        let header = pipeline::peek_header(&stream)?;
        if header.pipeline != e.pipeline {
            return Err(SzError::corrupt(format!(
                "index pipeline '{}' disagrees with stream header '{}'",
                e.pipeline, header.pipeline
            )));
        }
        let field = compressor.decompress(&stream)?;
        let mut expect = e.field_dims.clone();
        expect[0] = e.rows.1 - e.rows.0;
        if field.shape.dims() != expect.as_slice() {
            return Err(SzError::corrupt(format!(
                "chunk {} of {}: decoded dims {:?}, index says {:?}",
                e.chunk_index,
                e.field,
                field.shape.dims(),
                expect
            )));
        }
        self.counters.chunks_decoded.fetch_add(1, Ordering::Relaxed);
        let field = Arc::new(field);
        if let Some(k) = key {
            self.cache.insert(k, Arc::clone(&field));
        }
        Ok(field)
    }

    /// Fetch the compressed payload bytes of index entry `entry_id`
    /// (position in [`Self::index`]`().entries`) without decoding —
    /// CRC-verified on v2 containers. The passthrough behind the HTTP
    /// server's `/raw` endpoint, where clients decode on their side.
    pub fn chunk_payload(&self, entry_id: usize) -> Result<Vec<u8>> {
        let e = self.index.entries.get(entry_id).ok_or_else(|| {
            SzError::config(format!(
                "chunk {entry_id} out of range ({} index entries)",
                self.index.entries.len()
            ))
        })?;
        self.fetch_verified(e)
    }

    /// Decode the given entry ids across the worker pool
    /// ([`crate::util::par_for_each`], the coordinator's fan-out shape);
    /// results come back in input order.
    fn decode_many(&self, ids: &[usize]) -> Result<Vec<Arc<Field>>> {
        let n = ids.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let slots: Mutex<Vec<Option<Result<Arc<Field>>>>> =
            Mutex::new((0..n).map(|_| None).collect());
        crate::util::par_for_each(n, self.workers, |i| {
            let r = self.decode_entry(ids[i]);
            slots.lock().unwrap()[i] = Some(r);
        });
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|slot| slot.expect("every slot filled by the pool"))
            .collect()
    }

    /// Extract rows `[rows.start, rows.end)` of `field`, decoding only the
    /// chunks that overlap the request. The result is exactly the
    /// requested sub-field (dims `[rows.len(), ...rest]`), bit-identical
    /// to slicing a full decompression.
    pub fn read_region(&self, field: &str, rows: Range<usize>) -> Result<Field> {
        let fm = self.field_meta(field)?;
        let total_rows = fm.dims[0];
        if rows.start >= rows.end {
            return Err(SzError::config(format!(
                "empty row range {}..{} for field '{field}'",
                rows.start, rows.end
            )));
        }
        if rows.end > total_rows {
            return Err(SzError::config(format!(
                "row range {}..{} outside field '{field}' with {total_rows} rows",
                rows.start, rows.end
            )));
        }
        let overlap: Vec<usize> = fm
            .entry_ids
            .iter()
            .copied()
            .filter(|&id| {
                let (s, e) = self.index.entries[id].rows;
                e > rows.start && s < rows.end
            })
            .collect();
        let decoded = self.decode_many(&overlap)?;
        // borrow fully-covered chunks, own only the sliced boundary ones —
        // concat is then the single copy into the output buffer
        enum Part<'f> {
            Whole(&'f FieldValues),
            Sliced(FieldValues),
        }
        let mut parts: Vec<Part> = Vec::with_capacity(decoded.len());
        for (&id, chunk) in overlap.iter().zip(&decoded) {
            let (c_start, c_end) = self.index.entries[id].rows;
            let lo = rows.start.max(c_start) - c_start;
            let hi = rows.end.min(c_end) - c_start;
            if lo == 0 && hi == c_end - c_start {
                parts.push(Part::Whole(&chunk.values));
            } else {
                parts.push(Part::Sliced(slice_rows(chunk, (lo, hi))?.values));
            }
        }
        let values = FieldValues::concat(parts.iter().map(|p| match p {
            Part::Whole(v) => *v,
            Part::Sliced(v) => v,
        }))?;
        let mut dims = fm.dims.clone();
        dims[0] = rows.end - rows.start;
        Field::new(fm.name.clone(), &dims, values)
    }

    /// Read one full field (all its chunks, in parallel).
    pub fn read_field(&self, field: &str) -> Result<Field> {
        let total_rows = self.field_meta(field)?.dims[0];
        self.read_region(field, 0..total_rows)
    }

    /// Read every field: one parallel fan-out over all chunks, then
    /// per-field reassembly in order of first appearance. The batch path
    /// behind [`crate::container::decompress_container`].
    pub fn read_all(&self) -> Result<Vec<Field>> {
        let all_ids: Vec<usize> = (0..self.index.entries.len()).collect();
        let decoded = self.decode_many(&all_ids)?;
        let mut out = Vec::with_capacity(self.fields.len());
        for fm in &self.fields {
            let values = FieldValues::concat(
                fm.entry_ids.iter().map(|&id| &decoded[id].values),
            )?;
            out.push(Field::new(fm.name.clone(), &fm.dims, values)?);
        }
        Ok(out)
    }

    /// Fetch every chunk payload and verify its CRC-32 without decoding;
    /// returns the number of chunks whose checksum was checked (0 for v1
    /// containers, which carry none). The serve path runs this on every
    /// artifact before publishing it.
    pub fn verify_checksums(&self) -> Result<u64> {
        let n = self.index.entries.len();
        if n == 0 || self.version < container::VERSION_V2 {
            return Ok(0);
        }
        let failure: Mutex<Option<SzError>> = Mutex::new(None);
        crate::util::par_for_each(n, self.workers, |i| {
            if failure.lock().unwrap().is_some() {
                return; // a mismatch was already found; stop fetching
            }
            if let Err(e) = self.fetch_verified(&self.index.entries[i]) {
                failure.lock().unwrap().get_or_insert(e);
            }
        });
        if let Some(e) = failure.into_inner().unwrap() {
            return Err(e);
        }
        Ok(n as u64)
    }
}

/// Validate per-field chunk coverage once at open time: every field's
/// chunks must be duplicate-free, complete (`chunk_count` of them), agree
/// on dims, and tile `0..dims[0]` contiguously. Region reads then trust
/// the index without re-validating per query.
fn validate_coverage(index: &ContainerIndex) -> Result<Vec<FieldMeta>> {
    let mut fields: Vec<FieldMeta> = Vec::new();
    for (id, e) in index.entries.iter().enumerate() {
        match fields.iter_mut().find(|f| f.name == e.field) {
            Some(f) => f.entry_ids.push(id),
            None => fields.push(FieldMeta {
                name: e.field.clone(),
                dims: e.field_dims.clone(),
                entry_ids: vec![id],
            }),
        }
    }
    for fm in &mut fields {
        fm.entry_ids.sort_by_key(|&id| index.entries[id].chunk_index);
        let first = &index.entries[fm.entry_ids[0]];
        if fm.entry_ids.len() != first.chunk_count {
            return Err(SzError::corrupt(format!(
                "field {}: have {} of {} chunks",
                fm.name,
                fm.entry_ids.len(),
                first.chunk_count
            )));
        }
        let mut next_row = 0usize;
        for (i, &id) in fm.entry_ids.iter().enumerate() {
            let e = &index.entries[id];
            if e.chunk_index != i || e.field_dims != fm.dims || e.chunk_count != first.chunk_count
            {
                return Err(SzError::corrupt(format!(
                    "field {}: inconsistent chunk metadata at {i}",
                    fm.name
                )));
            }
            if e.rows.0 != next_row {
                return Err(SzError::corrupt(format!(
                    "field {}: row gap at chunk {i} (expected start {next_row}, got {})",
                    fm.name, e.rows.0
                )));
            }
            next_row = e.rows.1;
        }
        if next_row != fm.dims[0] {
            return Err(SzError::corrupt(format!(
                "field {}: chunks cover {next_row} of {} rows",
                fm.name, fm.dims[0]
            )));
        }
    }
    Ok(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JobConfig;
    use crate::coordinator::Coordinator;
    use crate::pipeline::ErrorBound;
    use crate::util::{prop, rng::Pcg32};
    use std::io::Cursor;

    /// 24 rows of 12x12, 3 rows per chunk -> 8 chunks.
    fn sample_container(n_fields: usize) -> Vec<u8> {
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(1e-3),
            workers: 2,
            chunk_elems: 3 * 144,
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let mut rng = Pcg32::seeded(123);
        let fields: Vec<Field> = (0..n_fields)
            .map(|i| {
                let dims = [24usize, 12, 12];
                Field::f32(format!("f{i}"), &dims, prop::smooth_field(&mut rng, &dims))
                    .unwrap()
            })
            .collect();
        let (artifact, _) = coord.run_to_container(fields).unwrap();
        artifact
    }

    #[test]
    fn open_reads_index_without_payload_knowledge() {
        let artifact = sample_container(2);
        let r = ContainerReader::from_slice(&artifact).unwrap();
        assert_eq!(r.version(), container::VERSION_V2);
        assert_eq!(r.field_names(), vec!["f0", "f1"]);
        assert_eq!(r.field_dims("f0").unwrap(), &[24, 12, 12]);
        assert_eq!(r.field_chunks("f0").unwrap(), 8);
        assert_eq!(r.stats(), ReadStats::default(), "open must fetch no chunks");
    }

    #[test]
    fn roi_decodes_only_overlapping_chunks_bit_identical() {
        let artifact = sample_container(1);
        let full = container::decompress_container(&artifact, 2).unwrap().remove(0);

        // rows 7..11 overlap chunks [6,9) and [9,12) only
        let r = ContainerReader::from_slice(&artifact).unwrap().with_workers(4);
        let region = r.read_region("f0", 7..11).unwrap();
        assert_eq!(r.stats().chunks_decoded, 2, "must decode exactly 2 of 8 chunks");
        assert_eq!(region.shape.dims(), &[4, 12, 12]);
        assert_eq!(region.values, slice_rows(&full, (7, 11)).unwrap().values);

        // 1-chunk ROI
        let r = ContainerReader::from_slice(&artifact).unwrap();
        let one = r.read_region("f0", 3..6).unwrap();
        assert_eq!(r.stats().chunks_decoded, 1);
        assert_eq!(one.values, slice_rows(&full, (3, 6)).unwrap().values);

        // single-row request
        let r = ContainerReader::from_slice(&artifact).unwrap();
        let row = r.read_region("f0", 23..24).unwrap();
        assert_eq!(r.stats().chunks_decoded, 1);
        assert_eq!(row.shape.dims(), &[1, 12, 12]);
        assert_eq!(row.values, slice_rows(&full, (23, 24)).unwrap().values);
    }

    #[test]
    fn degenerate_ranges_and_unknown_fields_rejected() {
        let artifact = sample_container(1);
        let r = ContainerReader::from_slice(&artifact).unwrap();
        assert!(r.read_region("f0", 5..5).is_err(), "empty range");
        assert!(r.read_region("f0", 9..7).is_err(), "inverted range");
        assert!(r.read_region("f0", 20..25).is_err(), "past the last row");
        assert!(r.read_region("nope", 0..1).is_err(), "unknown field");
        assert_eq!(r.stats().chunks_decoded, 0, "rejections must not decode");
    }

    #[test]
    fn warm_cache_skips_fetch_and_decode() {
        let artifact = sample_container(1);
        let r = ContainerReader::from_slice(&artifact)
            .unwrap()
            .with_cache_bytes(1 << 20);
        let a = r.read_region("f0", 0..6).unwrap();
        let cold = r.stats();
        assert_eq!(cold.chunks_decoded, 2);
        assert_eq!(cold.cache_hits, 0);
        let b = r.read_region("f0", 0..6).unwrap();
        let warm = r.stats();
        assert_eq!(warm.chunks_decoded, 2, "no new decodes on the warm read");
        assert_eq!(warm.chunks_fetched, 2, "no new fetches either");
        assert_eq!(warm.cache_hits, 2);
        assert_eq!(a.values, b.values);
    }

    #[test]
    fn file_source_reads_only_requested_chunks() {
        let artifact = sample_container(1);
        let src = FileSource::new(Cursor::new(artifact.clone())).unwrap();
        let r = ContainerReader::new(Box::new(src)).unwrap();
        let region = r.read_region("f0", 0..3).unwrap();
        assert_eq!(region.shape.dims(), &[3, 12, 12]);
        let s = r.stats();
        assert_eq!(s.chunks_decoded, 1);
        assert!(
            s.bytes_fetched < artifact.len() as u64 / 2,
            "1 of 8 chunks must not fetch most of the artifact \
             ({} of {} bytes)",
            s.bytes_fetched,
            artifact.len()
        );
    }

    #[test]
    fn prefetch_source_serves_sequential_scan() {
        let artifact = sample_container(1);
        let file = FileSource::new(Cursor::new(artifact.clone())).unwrap();
        let pre = PrefetchSource::new(Box::new(file), 1 << 20);
        let r = ContainerReader::new(Box::new(pre)).unwrap().with_workers(1);
        let full = r.read_field("f0").unwrap();
        assert_eq!(full.shape.dims(), &[24, 12, 12]);
        assert_eq!(r.stats().chunks_decoded, 8);
    }

    #[test]
    fn corrupt_crc_rejected_cleanly() {
        let artifact = sample_container(1);
        let meta = container::read_index_meta(&artifact).unwrap();
        // flip one payload byte inside chunk 0
        let mut bad = artifact.clone();
        let target = meta.payload_offset + meta.index.entries[0].offset + 3;
        bad[target] ^= 0x40;
        let r = ContainerReader::from_slice(&bad).unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            r.read_region("f0", 0..3)
        }));
        match caught {
            Ok(Err(e)) => assert!(e.to_string().contains("crc32"), "{e}"),
            Ok(Ok(_)) => panic!("corrupt chunk decoded"),
            Err(_) => panic!("corrupt chunk panicked"),
        }
        // chunks outside the corruption stay readable
        assert!(r.read_region("f0", 3..6).is_ok());
        // whole-container decode hits the bad chunk too
        assert!(container::decompress_container(&bad, 2).is_err());
        // verify_checksums names the failure without decoding anything
        let r = ContainerReader::from_slice(&bad).unwrap();
        assert!(r.verify_checksums().is_err());
        assert_eq!(r.stats().chunks_decoded, 0);
    }

    #[test]
    fn truncated_payload_rejected_at_open() {
        let artifact = sample_container(1);
        // cut mid-payload: the index parses but the payload extent is short
        let cut = artifact.len() - 7;
        let err = ContainerReader::from_slice(&artifact[..cut]).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // same through a file-backed source
        let src = FileSource::new(Cursor::new(artifact[..cut].to_vec())).unwrap();
        assert!(ContainerReader::new(Box::new(src)).is_err());
    }

    #[test]
    fn v1_container_reads_without_checksums() {
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(1e-3),
            workers: 2,
            chunk_elems: 3 * 144,
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let mut rng = Pcg32::seeded(123);
        let dims = [24usize, 12, 12];
        let field =
            Field::f32("f0", &dims, prop::smooth_field(&mut rng, &dims)).unwrap();
        let mut chunks = Vec::new();
        coord.run(vec![field], |c| chunks.push(c)).unwrap();
        let v1 = container::pack_v1(&chunks).unwrap();
        let r = ContainerReader::from_slice(&v1).unwrap();
        assert_eq!(r.version(), container::VERSION_V1);
        assert_eq!(r.verify_checksums().unwrap(), 0, "v1 carries no checksums");
        let region = r.read_region("f0", 4..8).unwrap();
        assert_eq!(region.shape.dims(), &[4, 12, 12]);
        let s = r.stats();
        assert_eq!(s.crc_verified, 0);
        assert!(s.chunks_decoded >= 2);
    }

    #[test]
    fn shared_cache_scopes_artifacts_apart() {
        // two artifacts with an identically-named field share one cache;
        // the scope prefix must keep their chunks from aliasing
        let a = sample_container(1);
        let b = {
            let cfg = JobConfig {
                pipeline: "sz3-lr".into(),
                bound: ErrorBound::Abs(1e-3),
                workers: 2,
                chunk_elems: 3 * 144,
                queue_depth: 2,
                ..Default::default()
            };
            let coord = Coordinator::from_config(&cfg).unwrap();
            let mut rng = Pcg32::seeded(777); // different data, same name/shape
            let dims = [24usize, 12, 12];
            let f =
                Field::f32("f0", &dims, prop::smooth_field(&mut rng, &dims)).unwrap();
            let (artifact, _) = coord.run_to_container(vec![f]).unwrap();
            artifact
        };
        let shared = Arc::new(ChunkCache::new(8 << 20));
        let ra = ContainerReader::from_slice(&a)
            .unwrap()
            .with_shared_cache(Arc::clone(&shared), "a");
        let rb = ContainerReader::from_slice(&b)
            .unwrap()
            .with_shared_cache(Arc::clone(&shared), "b");
        let va = ra.read_region("f0", 0..3).unwrap();
        let vb = rb.read_region("f0", 0..3).unwrap();
        assert_ne!(va.values, vb.values, "distinct artifacts hold distinct data");
        assert_eq!(shared.len(), 2, "one scoped entry per artifact");
        // warm replays stay scoped: each reader hits its own entry
        assert_eq!(ra.read_region("f0", 0..3).unwrap().values, va.values);
        assert_eq!(rb.read_region("f0", 0..3).unwrap().values, vb.values);
        assert_eq!(ra.stats().cache_hits, 1);
        assert_eq!(rb.stats().cache_hits, 1);
    }

    #[test]
    fn chunk_payload_passthrough_matches_index() {
        let artifact = sample_container(1);
        let meta = container::read_index_meta(&artifact).unwrap();
        let r = ContainerReader::from_slice(&artifact).unwrap();
        let e = &meta.index.entries[2];
        let bytes = r.chunk_payload(2).unwrap();
        assert_eq!(bytes.len(), e.len);
        let expect = &artifact[meta.payload_offset + e.offset..][..e.len];
        assert_eq!(bytes.as_slice(), expect, "raw compressed stream, byte for byte");
        assert_eq!(r.stats().chunks_decoded, 0, "passthrough must not decode");
        assert!(r.stats().crc_verified >= 1, "v2 passthrough still CRC-checks");
        assert!(r.chunk_payload(999).is_err(), "out-of-range entry id");
        // payload extent accessor agrees with the parsed meta
        assert_eq!(r.payload_bytes(), meta.payload_len);
    }

    #[test]
    fn read_all_matches_legacy_batch_decode() {
        let artifact = sample_container(3);
        let via_reader = ContainerReader::from_slice(&artifact)
            .unwrap()
            .with_workers(4)
            .read_all()
            .unwrap();
        assert_eq!(via_reader.len(), 3);
        for (i, f) in via_reader.iter().enumerate() {
            assert_eq!(f.name, format!("f{i}"));
            assert_eq!(f.shape.dims(), &[24, 12, 12]);
        }
    }
}
