//! Random-access container reader: indexed-seek reads over `SZ3C`
//! artifacts without materializing the whole container.
//!
//! [`ContainerReader`] parses only the chunk index (via
//! [`crate::container::read_index_meta`], which needs an index-covering
//! prefix, not the payload), then fetches chunk payloads on demand through
//! a [`ChunkSource`] — an in-memory slice, a seekable file, or a
//! prefetching wrapper. On top of that it offers:
//!
//! * **Region-of-interest extraction** — [`ContainerReader::read_region`]
//!   decodes only the chunks overlapping a row range (in parallel, the
//!   same scoped worker-pool pattern as the coordinator) and assembles
//!   exactly the requested sub-field.
//! * **Decoded-chunk LRU cache** — keyed by `(field, chunk_index)` and
//!   budgeted in **bytes** ([`ChunkCache`]), so repeated serve-path
//!   queries hit warm chunks instead of re-decoding. A cache can be
//!   private to one reader ([`ContainerReader::with_cache_bytes`]) or
//!   shared, scope-prefixed, across every artifact a server holds open
//!   ([`ContainerReader::with_shared_cache`] — the `sz3 serve-http`
//!   deployment shape, one `--cache-mb` knob for the whole process).
//! * **Integrity on every fetch** — v2+ containers carry a CRC-32 per
//!   chunk, verified before any byte reaches a decoder; the inner `SZ3R`
//!   header's pipeline name is cross-checked against the index; decoded
//!   dims are verified against the declared row range.
//! * **Snapshot axis** — v3 series artifacts expose
//!   [`ContainerReader::snapshot_count`] / `snapshot_tags`, and
//!   [`ContainerReader::read_region_at`] reads any timestep; chunks
//!   stored as snapshot residuals are resolved by walking the delta
//!   chain back to the nearest cached or direct ancestor (baseline links
//!   validated once at open), so a warm cache answers in one hop.
//!
//! This is the *single* seek/verify/decode path:
//! [`crate::container::decompress_container`] and
//! [`crate::container::decompress_single_field`] are thin wrappers over
//! [`ContainerReader::read_all`].

pub mod cache;
pub mod source;

pub use cache::{ChunkCache, ChunkKey};
pub use source::{ChunkSource, FileSource, PrefetchSource, SliceSource};

use crate::container::{self, ChunkEntry, ContainerIndex};
use crate::coordinator::slice_rows;
use crate::data::{Field, FieldValues};
use crate::error::{Result, SzError};
use crate::obs;
use crate::pipeline;
use crate::util::crc32::crc32;
use std::ops::Range;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Initial prefix size tried when parsing the index from a source; doubled
/// until the index parses or the whole artifact has been read.
const INDEX_PREFIX_PROBE: usize = 1 << 14;

/// Monotonic counters describing what a reader actually did — the decode
/// counters the ROI tests assert on, and the serve path's observability.
#[derive(Default)]
struct Counters {
    chunks_fetched: AtomicU64,
    bytes_fetched: AtomicU64,
    crc_verified: AtomicU64,
    chunks_decoded: AtomicU64,
    cache_hits: AtomicU64,
    delta_applied: AtomicU64,
}

/// Snapshot of a reader's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReadStats {
    /// Chunk payloads fetched from the source.
    pub chunks_fetched: u64,
    /// Payload bytes fetched from the source.
    pub bytes_fetched: u64,
    /// Chunks whose CRC-32 was checked (0 for v1 containers).
    pub crc_verified: u64,
    /// Chunks run through a decompression pipeline.
    pub chunks_decoded: u64,
    /// Decodes avoided by the warm-chunk cache.
    pub cache_hits: u64,
    /// Delta chunks resolved against their snapshot baseline (0 outside
    /// v3 series artifacts).
    pub delta_applied: u64,
}

/// Per-`(snapshot, field)` view assembled from the index at open time:
/// entry ids sorted by chunk position, with coverage already validated.
struct FieldMeta {
    snapshot: usize,
    name: String,
    dims: Vec<usize>,
    /// Indices into `index.entries`, sorted by `chunk_index`.
    entry_ids: Vec<usize>,
}

/// Indexed-seek reader over one `SZ3C` container.
pub struct ContainerReader<'a> {
    source: Box<dyn ChunkSource + 'a>,
    index: ContainerIndex,
    fields: Vec<FieldMeta>,
    /// For each entry: the entry id of its delta baseline — `Some` exactly
    /// when the entry is delta-flagged, resolved and validated at open.
    baseline_of: Vec<Option<usize>>,
    version: u8,
    payload_offset: u64,
    payload_len: u64,
    workers: usize,
    cache: Arc<ChunkCache>,
    /// Prefix prepended to field names in cache keys so artifacts sharing
    /// one cache cannot collide (empty for a private cache).
    cache_scope: String,
    counters: Counters,
}

impl<'a> ContainerReader<'a> {
    /// Open a container through any [`ChunkSource`]: reads an
    /// index-covering prefix (growing geometrically — the payload is never
    /// touched), validates every entry, and verifies per-field coverage
    /// (complete, duplicate-free, contiguous rows) so later region reads
    /// can trust the index.
    pub fn new(source: Box<dyn ChunkSource + 'a>) -> Result<Self> {
        let total = source.len();
        // magic/version screen first: a non-container or unsupported
        // artifact is decidable from the first 5 bytes — don't walk a
        // multi-GB file with the growing-prefix loop below just to report
        // an error the header already proves
        let mut head = [0u8; 5];
        if total < head.len() as u64 {
            return Err(SzError::corrupt("container shorter than its header"));
        }
        source.read_at(0, &mut head)?;
        if &head[..4] != container::CONTAINER_MAGIC {
            return Err(SzError::corrupt("bad container magic"));
        }
        if head[4] < container::VERSION_V1 || head[4] > container::VERSION_V3 {
            return Err(SzError::corrupt(format!(
                "unsupported container version {}",
                head[4]
            )));
        }
        let mut prefix_len = (INDEX_PREFIX_PROBE as u64).min(total) as usize;
        let meta = loop {
            let mut prefix = vec![0u8; prefix_len];
            source.read_at(0, &mut prefix)?;
            match container::read_index_meta(&prefix) {
                Ok(meta) => break meta,
                // only buffer exhaustion means "the index is longer than
                // this prefix" — grow and retry; validation errors (bad
                // ranges, overflow, ...) are verdicts and fail fast
                // without walking the rest of a multi-GB artifact
                Err(e) if e.is_exhaustion() && (prefix_len as u64) < total => {
                    prefix_len = ((prefix_len as u64) * 2).min(total) as usize;
                }
                Err(e) => return Err(e),
            }
        };
        let payload_end = (meta.payload_offset as u64)
            .checked_add(meta.payload_len)
            .ok_or_else(|| SzError::corrupt("payload extent overflows"))?;
        if payload_end > total {
            return Err(SzError::corrupt(format!(
                "container truncated: payload ends at byte {payload_end}, \
                 source holds {total}"
            )));
        }
        let (fields, baseline_of) = validate_coverage(&meta.index)?;
        Ok(ContainerReader {
            source,
            index: meta.index,
            fields,
            baseline_of,
            version: meta.version,
            payload_offset: meta.payload_offset as u64,
            payload_len: meta.payload_len,
            workers: crate::util::default_workers(),
            cache: Arc::new(ChunkCache::new(0)),
            cache_scope: String::new(),
            counters: Counters::default(),
        })
    }

    /// Reader over a fully-resident artifact.
    pub fn from_slice(stream: &'a [u8]) -> Result<Self> {
        Self::new(Box::new(SliceSource::new(stream)))
    }

    /// Reader over a container file — only the index and requested chunks
    /// are ever read from disk.
    pub fn open_path(path: impl AsRef<Path>) -> Result<ContainerReader<'static>> {
        ContainerReader::new(Box::new(FileSource::open(path)?))
    }

    /// Cap the parallel-decode fan-out (defaults to the core count).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Enable a private decoded-chunk LRU cache with a budget of `bytes`
    /// (decoded payload bytes plus a small per-entry overhead; 0 disables).
    pub fn with_cache_bytes(mut self, bytes: usize) -> Self {
        self.cache = Arc::new(ChunkCache::new(bytes));
        self.cache_scope = String::new();
        self
    }

    /// Attach a cache shared with other readers, namespaced by `scope`
    /// (typically the artifact id) so identical field names in different
    /// artifacts occupy distinct entries. This is how `sz3 serve-http`
    /// puts every open artifact behind one process-wide `--cache-mb`
    /// budget.
    pub fn with_shared_cache(mut self, cache: Arc<ChunkCache>, scope: &str) -> Self {
        self.cache = cache;
        self.cache_scope = if scope.is_empty() {
            String::new()
        } else {
            // unit separator: cannot appear in a scope id derived from a
            // file stem, so "a" + field "b" never aliases scope "ab"
            format!("{scope}\u{1f}")
        };
        self
    }

    /// The decoded-chunk cache this reader charges against.
    pub fn cache(&self) -> &Arc<ChunkCache> {
        &self.cache
    }

    /// Container format version (1, 2 or 3).
    pub fn version(&self) -> u8 {
        self.version
    }

    /// Total payload bytes (the concatenated compressed chunk streams,
    /// excluding the index).
    pub fn payload_bytes(&self) -> u64 {
        self.payload_len
    }

    /// The parsed chunk index.
    pub fn index(&self) -> &ContainerIndex {
        &self.index
    }

    /// Diagnostic label of the underlying source.
    pub fn source_kind(&self) -> &'static str {
        self.source.kind()
    }

    /// Number of snapshots the artifact holds (1 for v1/v2 containers).
    pub fn snapshot_count(&self) -> usize {
        self.index.snapshot_count()
    }

    /// Per-snapshot timestamp tags, indexed by snapshot id (a single
    /// empty tag for v1/v2 containers).
    pub fn snapshot_tags(&self) -> &[String] {
        &self.index.snapshots
    }

    /// Field names of snapshot `snapshot`, in order of first appearance.
    pub fn field_names_at(&self, snapshot: usize) -> Vec<&str> {
        self.fields
            .iter()
            .filter(|f| f.snapshot == snapshot)
            .map(|f| f.name.as_str())
            .collect()
    }

    /// Field names of the first snapshot, in order of first appearance —
    /// the whole index for v1/v2 containers.
    pub fn field_names(&self) -> Vec<&str> {
        self.field_names_at(0)
    }

    /// Full dims of `field` (first snapshot).
    pub fn field_dims(&self, field: &str) -> Result<&[usize]> {
        Ok(&self.field_meta(0, field)?.dims)
    }

    /// Full dims of `field` at snapshot `snapshot`.
    pub fn field_dims_at(&self, snapshot: usize, field: &str) -> Result<&[usize]> {
        Ok(&self.field_meta(snapshot, field)?.dims)
    }

    /// Number of chunks `field` is sharded into (first snapshot).
    pub fn field_chunks(&self, field: &str) -> Result<usize> {
        Ok(self.field_meta(0, field)?.entry_ids.len())
    }

    /// Snapshot of the decode/fetch counters.
    pub fn stats(&self) -> ReadStats {
        ReadStats {
            chunks_fetched: self.counters.chunks_fetched.load(Ordering::Relaxed),
            bytes_fetched: self.counters.bytes_fetched.load(Ordering::Relaxed),
            crc_verified: self.counters.crc_verified.load(Ordering::Relaxed),
            chunks_decoded: self.counters.chunks_decoded.load(Ordering::Relaxed),
            cache_hits: self.counters.cache_hits.load(Ordering::Relaxed),
            delta_applied: self.counters.delta_applied.load(Ordering::Relaxed),
        }
    }

    fn field_meta(&self, snapshot: usize, field: &str) -> Result<&FieldMeta> {
        if snapshot >= self.snapshot_count() {
            return Err(SzError::config(format!(
                "snapshot {snapshot} out of range ({} snapshots)",
                self.snapshot_count()
            )));
        }
        self.fields
            .iter()
            .find(|f| f.snapshot == snapshot && f.name == field)
            .ok_or_else(|| {
                SzError::config(format!(
                    "snapshot {snapshot} has no field '{field}' (holds {:?})",
                    self.field_names_at(snapshot)
                ))
            })
    }

    /// Fetch one chunk's payload bytes, CRC-verified when the index
    /// carries a checksum (v2).
    fn fetch_verified(&self, e: &ChunkEntry) -> Result<Vec<u8>> {
        let offset = self
            .payload_offset
            .checked_add(e.offset as u64)
            .ok_or_else(|| SzError::corrupt("chunk offset overflows"))?;
        let t_fetch = Instant::now();
        let mut buf = vec![0u8; e.len];
        self.source.read_at(offset, &mut buf)?;
        obs::READER_FETCH_US.observe_since(t_fetch);
        self.counters.chunks_fetched.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_fetched.fetch_add(e.len as u64, Ordering::Relaxed);
        if let Some(expect) = e.crc32 {
            let t_crc = Instant::now();
            let got = crc32(&buf);
            obs::READER_CRC_US.observe_since(t_crc);
            if got != expect {
                return Err(SzError::corrupt(format!(
                    "chunk {} of '{}': crc32 mismatch (index {expect:#010x}, \
                     payload {got:#010x})",
                    e.chunk_index, e.field
                )));
            }
            self.counters.crc_verified.fetch_add(1, Ordering::Relaxed);
        }
        Ok(buf)
    }

    /// Cache key of entry `id` — `None` when caching is off. The key
    /// embeds the snapshot id (unit-separated from the field name) so a
    /// series' identically-named fields occupy distinct entries.
    fn cache_key(&self, id: usize) -> Option<ChunkKey> {
        let e = self.index.entries.get(id)?;
        // only pay the key's String build when a cache is actually on
        (self.cache.budget() > 0).then(|| {
            (
                format!("{}{}\u{1e}{}", self.cache_scope, e.snapshot, e.field),
                e.chunk_index,
            )
        })
    }

    /// Fetch → verify → rebuild the stage stack from the index pipeline
    /// spec (cross-checked against the inner stream header) → decode →
    /// dims check. For a delta entry this yields the *residual* field,
    /// not the snapshot.
    fn decode_stream(&self, e: &ChunkEntry) -> Result<Field> {
        let stream = self.fetch_verified(e)?;
        let t_decode = Instant::now();
        let compressor = pipeline::build(&e.pipeline).map_err(|err| {
            pipeline::spec::unknown_pipeline_error("chunk index", &e.pipeline, &err)
        })?;
        let header = pipeline::peek_header(&stream)?;
        if header.pipeline != e.pipeline {
            return Err(SzError::corrupt(format!(
                "index pipeline '{}' disagrees with stream header '{}'",
                e.pipeline, header.pipeline
            )));
        }
        let field = compressor.decompress(&stream)?;
        let mut expect = e.field_dims.clone();
        expect[0] = e.rows.1 - e.rows.0;
        if field.shape.dims() != expect.as_slice() {
            return Err(SzError::corrupt(format!(
                "chunk {} of {}: decoded dims {:?}, index says {:?}",
                e.chunk_index,
                e.field,
                field.shape.dims(),
                expect
            )));
        }
        obs::READER_DECODE_US.observe_since(t_decode);
        self.counters.chunks_decoded.fetch_add(1, Ordering::Relaxed);
        Ok(field)
    }

    /// Reconstruct entry `baseline + residual` and count the resolution.
    fn apply_delta(&self, baseline: &Field, residual: &Field) -> Result<Field> {
        self.counters.delta_applied.fetch_add(1, Ordering::Relaxed);
        container::delta::apply(baseline, residual)
    }

    /// Decode one index entry into its reconstructed snapshot data:
    /// cache lookup, else walk the delta chain back to the nearest cached
    /// or direct ancestor, then roll forward applying residuals, caching
    /// every level on the way (so a warm cache resolves chains in one
    /// hop). Iterative on purpose — chain depth equals the snapshot
    /// count, which must not become a stack depth.
    fn decode_entry(&self, id: usize) -> Result<Arc<Field>> {
        let mut chain: Vec<usize> = Vec::new();
        let mut base: Option<Arc<Field>> = None;
        let mut cur = Some(id);
        while let Some(c) = cur {
            if let Some(k) = self.cache_key(c) {
                if let Some(hit) = self.cache.get(&k) {
                    self.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
                    base = Some(hit);
                    break;
                }
            }
            chain.push(c);
            // None exactly when entry `c` is direct — the chain ends
            cur = self.baseline_of.get(c).copied().flatten();
        }
        for &c in chain.iter().rev() {
            let e = self
                .index
                .entries
                .get(c)
                .ok_or_else(|| SzError::corrupt("delta chain names an entry outside the index"))?;
            let decoded = self.decode_stream(e)?;
            let field = if e.delta {
                let b = base.as_ref().ok_or_else(|| {
                    SzError::corrupt("delta chunk reached without a decoded baseline")
                })?;
                Arc::new(self.apply_delta(b, &decoded)?)
            } else {
                Arc::new(decoded)
            };
            if let Some(k) = self.cache_key(c) {
                self.cache.insert(k, Arc::clone(&field));
            }
            base = Some(field);
        }
        base.ok_or_else(|| SzError::corrupt("empty delta chain with no cache hit"))
    }

    /// Fetch the compressed payload bytes of index entry `entry_id`
    /// (position in [`Self::index`]`().entries`) without decoding —
    /// CRC-verified on v2 containers. The passthrough behind the HTTP
    /// server's `/raw` endpoint, where clients decode on their side.
    pub fn chunk_payload(&self, entry_id: usize) -> Result<Vec<u8>> {
        let e = self.index.entries.get(entry_id).ok_or_else(|| {
            SzError::config(format!(
                "chunk {entry_id} out of range ({} index entries)",
                self.index.entries.len()
            ))
        })?;
        self.fetch_verified(e)
    }

    /// Decode the given entry ids across the worker pool
    /// ([`crate::util::par_for_each`], the coordinator's fan-out shape);
    /// results come back in input order.
    fn decode_many(&self, ids: &[usize]) -> Result<Vec<Arc<Field>>> {
        let n = ids.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let slots: Mutex<Vec<Option<Result<Arc<Field>>>>> =
            Mutex::new((0..n).map(|_| None).collect());
        crate::util::par_for_each(n, self.workers, |i| {
            let Some(&id) = ids.get(i) else { return };
            let r = self.decode_entry(id);
            if let Ok(mut guard) = slots.lock() {
                if let Some(slot) = guard.get_mut(i) {
                    *slot = Some(r);
                }
            }
        });
        slots
            .into_inner()
            .map_err(|_| SzError::Runtime("decode pool poisoned its result slots".into()))?
            .into_iter()
            .map(|slot| {
                slot.unwrap_or_else(|| {
                    Err(SzError::Runtime("decode pool left a slot unfilled".into()))
                })
            })
            .collect()
    }

    /// Extract rows `[rows.start, rows.end)` of `field` at snapshot 0 —
    /// see [`Self::read_region_at`]. For v1/v2 containers this is the
    /// whole artifact; for a series it reads the first snapshot.
    pub fn read_region(&self, field: &str, rows: Range<usize>) -> Result<Field> {
        self.read_region_at(0, field, rows)
    }

    /// Extract rows `[rows.start, rows.end)` of `field` at snapshot
    /// `snapshot`, decoding only the chunks that overlap the request
    /// (resolving delta chains through the decoded-chunk cache). The
    /// result is exactly the requested sub-field (dims
    /// `[rows.len(), ...rest]`), bit-identical to slicing a full
    /// decompression of that snapshot.
    pub fn read_region_at(
        &self,
        snapshot: usize,
        field: &str,
        rows: Range<usize>,
    ) -> Result<Field> {
        let fm = self.field_meta(snapshot, field)?;
        let total_rows = fm.dims[0];
        if rows.start >= rows.end {
            return Err(SzError::config(format!(
                "empty row range {}..{} for field '{field}'",
                rows.start, rows.end
            )));
        }
        if rows.end > total_rows {
            return Err(SzError::config(format!(
                "row range {}..{} outside field '{field}' with {total_rows} rows",
                rows.start, rows.end
            )));
        }
        let overlap: Vec<usize> = fm
            .entry_ids
            .iter()
            .copied()
            .filter(|&id| {
                self.index
                    .entries
                    .get(id)
                    .is_some_and(|e| e.rows.1 > rows.start && e.rows.0 < rows.end)
            })
            .collect();
        let decoded = self.decode_many(&overlap)?;
        // borrow fully-covered chunks, own only the sliced boundary ones —
        // concat is then the single copy into the output buffer
        enum Part<'f> {
            Whole(&'f FieldValues),
            Sliced(FieldValues),
        }
        let mut parts: Vec<Part> = Vec::with_capacity(decoded.len());
        for (&id, chunk) in overlap.iter().zip(&decoded) {
            let (c_start, c_end) = self
                .index
                .entries
                .get(id)
                .ok_or_else(|| {
                    SzError::Runtime("overlap set names an entry outside the index".into())
                })?
                .rows;
            let lo = rows.start.max(c_start) - c_start;
            let hi = rows.end.min(c_end) - c_start;
            if lo == 0 && hi == c_end - c_start {
                parts.push(Part::Whole(&chunk.values));
            } else {
                parts.push(Part::Sliced(slice_rows(chunk, (lo, hi))?.values));
            }
        }
        let values = FieldValues::concat(parts.iter().map(|p| match p {
            Part::Whole(v) => *v,
            Part::Sliced(v) => v,
        }))?;
        let mut dims = fm.dims.clone();
        dims[0] = rows.end - rows.start;
        Field::new(fm.name.clone(), &dims, values)
    }

    /// Read one full field at snapshot 0 (all its chunks, in parallel).
    pub fn read_field(&self, field: &str) -> Result<Field> {
        self.read_field_at(0, field)
    }

    /// Read one full field at snapshot `snapshot`.
    pub fn read_field_at(&self, snapshot: usize, field: &str) -> Result<Field> {
        let total_rows = self.field_meta(snapshot, field)?.dims[0];
        self.read_region_at(snapshot, field, 0..total_rows)
    }

    /// Read every field of every snapshot: chunks are grouped into delta
    /// chains (same field + chunk position across snapshots) and the
    /// chains fan out across the worker pool, so each compressed stream
    /// is decoded exactly once even when no cache is attached. Fields
    /// come back snapshot-major, in order of first appearance — the batch
    /// path behind [`crate::container::decompress_container`].
    pub fn read_all(&self) -> Result<Vec<Field>> {
        let n = self.index.entries.len();
        // chain = entry ids sharing (field, chunk_index), snapshot order;
        // within a chain each entry's baseline is an earlier element
        let mut chains: Vec<Vec<usize>> = Vec::new();
        {
            let mut chain_of: std::collections::HashMap<(&str, usize), usize> =
                std::collections::HashMap::new();
            let mut ordered: Vec<&FieldMeta> = self.fields.iter().collect();
            ordered.sort_by_key(|f| f.snapshot);
            for fm in ordered {
                for &id in &fm.entry_ids {
                    let Some(e) = self.index.entries.get(id) else { continue };
                    match chain_of.entry((e.field.as_str(), e.chunk_index)) {
                        std::collections::hash_map::Entry::Occupied(o) => {
                            if let Some(chain) = chains.get_mut(*o.get()) {
                                chain.push(id);
                            }
                        }
                        std::collections::hash_map::Entry::Vacant(v) => {
                            v.insert(chains.len());
                            chains.push(vec![id]);
                        }
                    }
                }
            }
        }
        let slots: Mutex<Vec<Option<Result<Arc<Field>>>>> =
            Mutex::new((0..n).map(|_| None).collect());
        crate::util::par_for_each(chains.len(), self.workers, |ci| {
            let Some(chain) = chains.get(ci) else { return };
            let mut prev: Option<Arc<Field>> = None;
            for &id in chain {
                let Some(e) = self.index.entries.get(id) else { break };
                let r = self.decode_stream(e).and_then(|decoded| {
                    if e.delta {
                        let b = prev.as_ref().ok_or_else(|| {
                            SzError::corrupt(
                                "delta chunk reached without a decoded baseline",
                            )
                        })?;
                        Ok(Arc::new(self.apply_delta(b, &decoded)?))
                    } else {
                        Ok(Arc::new(decoded))
                    }
                });
                let ok = r.is_ok();
                prev = r.as_ref().ok().map(Arc::clone);
                if let Ok(mut guard) = slots.lock() {
                    if let Some(slot) = guard.get_mut(id) {
                        *slot = Some(r);
                    }
                }
                if !ok {
                    break; // the rest of the chain cannot resolve
                }
            }
        });
        let mut slot_vec = slots
            .into_inner()
            .map_err(|_| SzError::Runtime("decode pool poisoned its result slots".into()))?;
        let mut out = Vec::with_capacity(self.fields.len());
        for fm in &self.fields {
            let mut parts = Vec::with_capacity(fm.entry_ids.len());
            for &id in &fm.entry_ids {
                match slot_vec.get_mut(id).and_then(|slot| slot.take()) {
                    Some(Ok(f)) => parts.push(f),
                    Some(Err(e)) => return Err(e),
                    None => {
                        return Err(SzError::corrupt(format!(
                            "chunk {} of '{}' left undecoded (broken delta chain)",
                            self.index.entries.get(id).map_or(0, |e| e.chunk_index),
                            fm.name
                        )))
                    }
                }
            }
            let values = FieldValues::concat(parts.iter().map(|f| &f.values))?;
            out.push(Field::new(fm.name.clone(), &fm.dims, values)?);
        }
        Ok(out)
    }

    /// Fetch every chunk payload and verify its CRC-32 without decoding;
    /// returns the number of chunks whose checksum was checked (0 for v1
    /// containers, which carry none). The serve path runs this on every
    /// artifact before publishing it.
    pub fn verify_checksums(&self) -> Result<u64> {
        let n = self.index.entries.len();
        if n == 0 || self.version < container::VERSION_V2 {
            return Ok(0);
        }
        let failure: Mutex<Option<SzError>> = Mutex::new(None);
        crate::util::par_for_each(n, self.workers, |i| {
            if let Ok(found) = failure.lock() {
                if found.is_some() {
                    return; // a mismatch was already found; stop fetching
                }
            }
            let Some(entry) = self.index.entries.get(i) else { return };
            if let Err(e) = self.fetch_verified(entry) {
                if let Ok(mut found) = failure.lock() {
                    found.get_or_insert(e);
                }
            }
        });
        match failure.into_inner() {
            Ok(Some(e)) => Err(e),
            Ok(None) => Ok(n as u64),
            Err(_) => Err(SzError::Runtime(
                "checksum pool poisoned its failure slot".into(),
            )),
        }
    }
}

/// Validate per-`(snapshot, field)` chunk coverage once at open time:
/// every field's chunks must be duplicate-free, complete (`chunk_count`
/// of them), agree on dims, and tile `0..dims[0]` contiguously; every
/// delta chunk must have a matching baseline chunk (same field, chunk
/// position, rows, dims) in the previous snapshot. Region reads then
/// trust the index — and the precomputed baseline links — without
/// re-validating per query.
fn validate_coverage(
    index: &ContainerIndex,
) -> Result<(Vec<FieldMeta>, Vec<Option<usize>>)> {
    let mut fields: Vec<FieldMeta> = Vec::new();
    for (id, e) in index.entries.iter().enumerate() {
        match fields
            .iter_mut()
            .find(|f| f.snapshot == e.snapshot && f.name == e.field)
        {
            Some(f) => f.entry_ids.push(id),
            None => fields.push(FieldMeta {
                snapshot: e.snapshot,
                name: e.field.clone(),
                dims: e.field_dims.clone(),
                entry_ids: vec![id],
            }),
        }
    }
    // snapshot-major order (stable within a snapshot) so read_all output
    // and field listings group naturally by timestep
    fields.sort_by_key(|f| f.snapshot);
    for fm in &mut fields {
        fm.entry_ids
            .sort_by_key(|&id| index.entries.get(id).map_or(0, |e| e.chunk_index));
        let first = fm
            .entry_ids
            .first()
            .and_then(|&id| index.entries.get(id))
            .ok_or_else(|| SzError::corrupt("field listed with no chunks"))?;
        if fm.entry_ids.len() != first.chunk_count {
            return Err(SzError::corrupt(format!(
                "field {}: have {} of {} chunks",
                fm.name,
                fm.entry_ids.len(),
                first.chunk_count
            )));
        }
        let mut next_row = 0usize;
        for (i, &id) in fm.entry_ids.iter().enumerate() {
            let e = index
                .entries
                .get(id)
                .ok_or_else(|| SzError::corrupt("field entry id outside the index"))?;
            if e.chunk_index != i || e.field_dims != fm.dims || e.chunk_count != first.chunk_count
            {
                return Err(SzError::corrupt(format!(
                    "field {}: inconsistent chunk metadata at {i}",
                    fm.name
                )));
            }
            if e.rows.0 != next_row {
                return Err(SzError::corrupt(format!(
                    "field {}: row gap at chunk {i} (expected start {next_row}, got {})",
                    fm.name, e.rows.0
                )));
            }
            next_row = e.rows.1;
        }
        if next_row != fm.dims[0] {
            return Err(SzError::corrupt(format!(
                "field {}: chunks cover {next_row} of {} rows",
                fm.name, fm.dims[0]
            )));
        }
    }
    let mut baseline_of: Vec<Option<usize>> = vec![None; index.entries.len()];
    for (id, e) in index.entries.iter().enumerate() {
        if !e.delta {
            continue;
        }
        // read_index_meta already rejected delta at snapshot 0
        let prev = fields
            .iter()
            .find(|f| f.snapshot + 1 == e.snapshot && f.name == e.field)
            .ok_or_else(|| {
                SzError::corrupt(format!(
                    "delta chunk {} of '{}': snapshot {} has no such field",
                    e.chunk_index,
                    e.field,
                    e.snapshot - 1
                ))
            })?;
        let b_id = *prev.entry_ids.get(e.chunk_index).ok_or_else(|| {
            SzError::corrupt(format!(
                "delta chunk {} of '{}': no baseline chunk in snapshot {}",
                e.chunk_index,
                e.field,
                e.snapshot - 1
            ))
        })?;
        let b = index
            .entries
            .get(b_id)
            .ok_or_else(|| SzError::corrupt("baseline entry id outside the index"))?;
        if b.rows != e.rows || b.field_dims != e.field_dims {
            return Err(SzError::corrupt(format!(
                "delta chunk {} of '{}': baseline rows {:?} disagree with {:?}",
                e.chunk_index, e.field, b.rows, e.rows
            )));
        }
        if let Some(slot) = baseline_of.get_mut(id) {
            *slot = Some(b_id);
        }
    }
    Ok((fields, baseline_of))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JobConfig;
    use crate::coordinator::Coordinator;
    use crate::pipeline::ErrorBound;
    use crate::util::{prop, rng::Pcg32};
    use std::io::Cursor;

    /// 24 rows of 12x12, 3 rows per chunk -> 8 chunks.
    fn sample_container(n_fields: usize) -> Vec<u8> {
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(1e-3),
            workers: 2,
            chunk_elems: 3 * 144,
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let mut rng = Pcg32::seeded(123);
        let fields: Vec<Field> = (0..n_fields)
            .map(|i| {
                let dims = [24usize, 12, 12];
                Field::f32(format!("f{i}"), &dims, prop::smooth_field(&mut rng, &dims))
                    .unwrap()
            })
            .collect();
        let (artifact, _) = coord.run_to_container(fields).unwrap();
        artifact
    }

    #[test]
    fn open_reads_index_without_payload_knowledge() {
        let artifact = sample_container(2);
        let r = ContainerReader::from_slice(&artifact).unwrap();
        assert_eq!(r.version(), container::VERSION_V3);
        assert_eq!(r.snapshot_count(), 1, "plain pack is a 1-snapshot artifact");
        assert_eq!(r.field_names(), vec!["f0", "f1"]);
        assert_eq!(r.field_dims("f0").unwrap(), &[24, 12, 12]);
        assert_eq!(r.field_chunks("f0").unwrap(), 8);
        assert_eq!(r.stats(), ReadStats::default(), "open must fetch no chunks");
    }

    #[test]
    fn roi_decodes_only_overlapping_chunks_bit_identical() {
        let artifact = sample_container(1);
        let full = container::decompress_container(&artifact, 2).unwrap().remove(0);

        // rows 7..11 overlap chunks [6,9) and [9,12) only
        let r = ContainerReader::from_slice(&artifact).unwrap().with_workers(4);
        let region = r.read_region("f0", 7..11).unwrap();
        assert_eq!(r.stats().chunks_decoded, 2, "must decode exactly 2 of 8 chunks");
        assert_eq!(region.shape.dims(), &[4, 12, 12]);
        assert_eq!(region.values, slice_rows(&full, (7, 11)).unwrap().values);

        // 1-chunk ROI
        let r = ContainerReader::from_slice(&artifact).unwrap();
        let one = r.read_region("f0", 3..6).unwrap();
        assert_eq!(r.stats().chunks_decoded, 1);
        assert_eq!(one.values, slice_rows(&full, (3, 6)).unwrap().values);

        // single-row request
        let r = ContainerReader::from_slice(&artifact).unwrap();
        let row = r.read_region("f0", 23..24).unwrap();
        assert_eq!(r.stats().chunks_decoded, 1);
        assert_eq!(row.shape.dims(), &[1, 12, 12]);
        assert_eq!(row.values, slice_rows(&full, (23, 24)).unwrap().values);
    }

    #[test]
    fn degenerate_ranges_and_unknown_fields_rejected() {
        let artifact = sample_container(1);
        let r = ContainerReader::from_slice(&artifact).unwrap();
        assert!(r.read_region("f0", 5..5).is_err(), "empty range");
        assert!(r.read_region("f0", 9..7).is_err(), "inverted range");
        assert!(r.read_region("f0", 20..25).is_err(), "past the last row");
        assert!(r.read_region("nope", 0..1).is_err(), "unknown field");
        assert_eq!(r.stats().chunks_decoded, 0, "rejections must not decode");
    }

    #[test]
    fn warm_cache_skips_fetch_and_decode() {
        let artifact = sample_container(1);
        let r = ContainerReader::from_slice(&artifact)
            .unwrap()
            .with_cache_bytes(1 << 20);
        let a = r.read_region("f0", 0..6).unwrap();
        let cold = r.stats();
        assert_eq!(cold.chunks_decoded, 2);
        assert_eq!(cold.cache_hits, 0);
        let b = r.read_region("f0", 0..6).unwrap();
        let warm = r.stats();
        assert_eq!(warm.chunks_decoded, 2, "no new decodes on the warm read");
        assert_eq!(warm.chunks_fetched, 2, "no new fetches either");
        assert_eq!(warm.cache_hits, 2);
        assert_eq!(a.values, b.values);
    }

    #[test]
    fn file_source_reads_only_requested_chunks() {
        let artifact = sample_container(1);
        let src = FileSource::new(Cursor::new(artifact.clone())).unwrap();
        let r = ContainerReader::new(Box::new(src)).unwrap();
        let region = r.read_region("f0", 0..3).unwrap();
        assert_eq!(region.shape.dims(), &[3, 12, 12]);
        let s = r.stats();
        assert_eq!(s.chunks_decoded, 1);
        assert!(
            s.bytes_fetched < artifact.len() as u64 / 2,
            "1 of 8 chunks must not fetch most of the artifact \
             ({} of {} bytes)",
            s.bytes_fetched,
            artifact.len()
        );
    }

    #[test]
    fn prefetch_source_serves_sequential_scan() {
        let artifact = sample_container(1);
        let file = FileSource::new(Cursor::new(artifact.clone())).unwrap();
        let pre = PrefetchSource::new(Box::new(file), 1 << 20);
        let r = ContainerReader::new(Box::new(pre)).unwrap().with_workers(1);
        let full = r.read_field("f0").unwrap();
        assert_eq!(full.shape.dims(), &[24, 12, 12]);
        assert_eq!(r.stats().chunks_decoded, 8);
    }

    #[test]
    fn corrupt_crc_rejected_cleanly() {
        let artifact = sample_container(1);
        let meta = container::read_index_meta(&artifact).unwrap();
        // flip one payload byte inside chunk 0
        let mut bad = artifact.clone();
        let target = meta.payload_offset + meta.index.entries[0].offset + 3;
        bad[target] ^= 0x40;
        let r = ContainerReader::from_slice(&bad).unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            r.read_region("f0", 0..3)
        }));
        match caught {
            Ok(Err(e)) => assert!(e.to_string().contains("crc32"), "{e}"),
            Ok(Ok(_)) => panic!("corrupt chunk decoded"),
            Err(_) => panic!("corrupt chunk panicked"),
        }
        // chunks outside the corruption stay readable
        assert!(r.read_region("f0", 3..6).is_ok());
        // whole-container decode hits the bad chunk too
        assert!(container::decompress_container(&bad, 2).is_err());
        // verify_checksums names the failure without decoding anything
        let r = ContainerReader::from_slice(&bad).unwrap();
        assert!(r.verify_checksums().is_err());
        assert_eq!(r.stats().chunks_decoded, 0);
    }

    #[test]
    fn truncated_payload_rejected_at_open() {
        let artifact = sample_container(1);
        // cut mid-payload: the index parses but the payload extent is short
        let cut = artifact.len() - 7;
        let err = ContainerReader::from_slice(&artifact[..cut]).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // same through a file-backed source
        let src = FileSource::new(Cursor::new(artifact[..cut].to_vec())).unwrap();
        assert!(ContainerReader::new(Box::new(src)).is_err());
    }

    #[test]
    fn v1_container_reads_without_checksums() {
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(1e-3),
            workers: 2,
            chunk_elems: 3 * 144,
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let mut rng = Pcg32::seeded(123);
        let dims = [24usize, 12, 12];
        let field =
            Field::f32("f0", &dims, prop::smooth_field(&mut rng, &dims)).unwrap();
        let mut chunks = Vec::new();
        coord.run(vec![field], |c| chunks.push(c)).unwrap();
        let v1 = container::pack_v1(&chunks).unwrap();
        let r = ContainerReader::from_slice(&v1).unwrap();
        assert_eq!(r.version(), container::VERSION_V1);
        assert_eq!(r.verify_checksums().unwrap(), 0, "v1 carries no checksums");
        let region = r.read_region("f0", 4..8).unwrap();
        assert_eq!(region.shape.dims(), &[4, 12, 12]);
        let s = r.stats();
        assert_eq!(s.crc_verified, 0);
        assert!(s.chunks_decoded >= 2);
    }

    #[test]
    fn shared_cache_scopes_artifacts_apart() {
        // two artifacts with an identically-named field share one cache;
        // the scope prefix must keep their chunks from aliasing
        let a = sample_container(1);
        let b = {
            let cfg = JobConfig {
                pipeline: "sz3-lr".into(),
                bound: ErrorBound::Abs(1e-3),
                workers: 2,
                chunk_elems: 3 * 144,
                queue_depth: 2,
                ..Default::default()
            };
            let coord = Coordinator::from_config(&cfg).unwrap();
            let mut rng = Pcg32::seeded(777); // different data, same name/shape
            let dims = [24usize, 12, 12];
            let f =
                Field::f32("f0", &dims, prop::smooth_field(&mut rng, &dims)).unwrap();
            let (artifact, _) = coord.run_to_container(vec![f]).unwrap();
            artifact
        };
        let shared = Arc::new(ChunkCache::new(8 << 20));
        let ra = ContainerReader::from_slice(&a)
            .unwrap()
            .with_shared_cache(Arc::clone(&shared), "a");
        let rb = ContainerReader::from_slice(&b)
            .unwrap()
            .with_shared_cache(Arc::clone(&shared), "b");
        let va = ra.read_region("f0", 0..3).unwrap();
        let vb = rb.read_region("f0", 0..3).unwrap();
        assert_ne!(va.values, vb.values, "distinct artifacts hold distinct data");
        assert_eq!(shared.len(), 2, "one scoped entry per artifact");
        // warm replays stay scoped: each reader hits its own entry
        assert_eq!(ra.read_region("f0", 0..3).unwrap().values, va.values);
        assert_eq!(rb.read_region("f0", 0..3).unwrap().values, vb.values);
        assert_eq!(ra.stats().cache_hits, 1);
        assert_eq!(rb.stats().cache_hits, 1);
    }

    #[test]
    fn chunk_payload_passthrough_matches_index() {
        let artifact = sample_container(1);
        let meta = container::read_index_meta(&artifact).unwrap();
        let r = ContainerReader::from_slice(&artifact).unwrap();
        let e = &meta.index.entries[2];
        let bytes = r.chunk_payload(2).unwrap();
        assert_eq!(bytes.len(), e.len);
        let expect = &artifact[meta.payload_offset + e.offset..][..e.len];
        assert_eq!(bytes.as_slice(), expect, "raw compressed stream, byte for byte");
        assert_eq!(r.stats().chunks_decoded, 0, "passthrough must not decode");
        assert!(r.stats().crc_verified >= 1, "v2 passthrough still CRC-checks");
        assert!(r.chunk_payload(999).is_err(), "out-of-range entry id");
        // payload extent accessor agrees with the parsed meta
        assert_eq!(r.payload_bytes(), meta.payload_len);
    }

    /// 3-snapshot smoothly-drifting series of one 12-row field, 3 rows
    /// per chunk → 4 chunks per snapshot, packed with delta mode on.
    fn sample_series() -> (Vec<u8>, Vec<Field>) {
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(1e-3),
            workers: 2,
            chunk_elems: 3 * 144,
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let snaps =
            container::fixtures::smooth_series(555, &[12, 12, 12], 3, 0.01, "rho");
        let originals: Vec<Field> =
            snaps.iter().map(|s| s.fields[0].clone()).collect();
        let (artifact, rep) = coord.run_series_to_container(snaps, true).unwrap();
        assert!(rep.delta_chunks > 0, "sample series must exercise delta: {rep}");
        (artifact, originals)
    }

    #[test]
    fn series_reader_resolves_delta_chains() {
        let (artifact, originals) = sample_series();
        let r = ContainerReader::from_slice(&artifact).unwrap().with_workers(2);
        assert_eq!(r.version(), container::VERSION_V3);
        assert_eq!(r.snapshot_count(), 3);
        assert_eq!(r.snapshot_tags(), &["t0", "t1", "t2"]);
        assert_eq!(r.field_names_at(2), vec!["rho"]);
        // every snapshot reconstructs within the bound (1% slack for the
        // one extra f32 rounding of baseline+residual reconstruction)
        for (t, orig) in originals.iter().enumerate() {
            let out = r.read_field_at(t, "rho").unwrap();
            assert_eq!(out.shape.dims(), orig.shape.dims());
            for (o, d) in
                orig.values.to_f64_vec().iter().zip(out.values.to_f64_vec())
            {
                assert!((o - d).abs() <= 1e-3 * 1.01, "snapshot {t}");
            }
        }
        // an ROI at the last snapshot is bit-identical to slicing the
        // full snapshot decode, and delta resolution is counted
        let full = r.read_field_at(2, "rho").unwrap();
        let r2 = ContainerReader::from_slice(&artifact).unwrap();
        let roi = r2.read_region_at(2, "rho", 4..8).unwrap();
        assert_eq!(roi.values, slice_rows(&full, (4, 8)).unwrap().values);
        // rows 4..8 overlap chunks 1 and 2; if either is delta at the
        // requested snapshot, its resolution must be counted
        if artifact_has_delta_at(&artifact, 2, &[1, 2]) {
            assert!(r2.stats().delta_applied > 0);
        }
        // read_all returns every snapshot, snapshot-major, decoding each
        // stream exactly once
        let r3 = ContainerReader::from_slice(&artifact).unwrap().with_workers(4);
        let all = r3.read_all().unwrap();
        assert_eq!(all.len(), 3);
        assert_eq!(
            r3.stats().chunks_decoded,
            r3.index().entries.len() as u64,
            "chain-grouped batch decode must decode each entry once"
        );
        assert_eq!(all[2].values, full.values);
        // snapshot bounds checked
        assert!(r.read_region_at(3, "rho", 0..1).is_err());
        assert!(r.read_field_at(9, "rho").is_err());
    }

    fn artifact_has_delta_at(artifact: &[u8], snapshot: usize, chunks: &[usize]) -> bool {
        container::read_index_meta(artifact)
            .unwrap()
            .index
            .entries
            .iter()
            .any(|e| e.snapshot == snapshot && e.delta && chunks.contains(&e.chunk_index))
    }

    #[test]
    fn warm_cache_resolves_delta_chain_in_one_hop() {
        let (artifact, _) = sample_series();
        let r = ContainerReader::from_slice(&artifact)
            .unwrap()
            .with_cache_bytes(8 << 20);
        r.read_region_at(2, "rho", 0..3).unwrap();
        let cold = r.stats();
        assert!(cold.chunks_decoded >= 1);
        r.read_region_at(2, "rho", 0..3).unwrap();
        let warm = r.stats();
        assert_eq!(
            warm.chunks_decoded, cold.chunks_decoded,
            "warm chain read must decode nothing new"
        );
        assert_eq!(warm.cache_hits, cold.cache_hits + 1);
        // intermediate snapshots of the chain were cached on the way, so
        // reading snapshot 1 directly is also warm (if it was on the chain)
        if cold.delta_applied >= 2 {
            let before = r.stats();
            r.read_region_at(1, "rho", 0..3).unwrap();
            assert_eq!(r.stats().chunks_decoded, before.chunks_decoded);
        }
    }

    #[test]
    fn read_all_matches_legacy_batch_decode() {
        let artifact = sample_container(3);
        let via_reader = ContainerReader::from_slice(&artifact)
            .unwrap()
            .with_workers(4)
            .read_all()
            .unwrap();
        assert_eq!(via_reader.len(), 3);
        for (i, f) in via_reader.iter().enumerate() {
            assert_eq!(f.name, format!("f{i}"));
            assert_eq!(f.shape.dims(), &[24, 12, 12]);
        }
    }
}
