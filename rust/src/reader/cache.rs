//! Byte-budgeted LRU cache of decoded chunks keyed by `(scope+field,
//! chunk_index)` — the serve-path accelerator: repeated region queries
//! over the same hot chunks skip fetch, CRC, and decode entirely.
//!
//! Accounting is by **bytes, not entries**: every cached chunk is charged
//! its decoded payload size plus a fixed per-entry overhead, and inserts
//! evict least-recently-used entries until the total charge fits the
//! budget again. One budget therefore governs real memory no matter how
//! chunk sizes vary across artifacts — which is what lets the HTTP server
//! share a single process-wide cache (`--cache-mb`) across every open
//! [`crate::reader::ContainerReader`]. Chunks larger than the whole
//! budget are served but never cached.
//!
//! Implementation: a `HashMap` of entries stamped with a monotonically
//! increasing access tick; eviction scans for the minimum tick. O(n) per
//! eviction is deliberate — budgets hold tens to hundreds of chunks, and
//! the scan is trivially cheaper than a decode it stands in for.

use crate::data::Field;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Cache key: (scoped field name, chunk index within the field). Readers
/// sharing one cache prefix the field name with a scope (see
/// [`crate::reader::ContainerReader::with_shared_cache`]) so identical
/// field names in different artifacts cannot collide.
pub type ChunkKey = (String, usize);

/// Fixed per-entry charge on top of the decoded payload: map slot, access
/// stamp, `Arc` bookkeeping. A round number — the point is that thousands
/// of tiny chunks cannot sneak past a small byte budget for free.
const ENTRY_OVERHEAD: usize = 96;

struct Entry {
    stamp: u64,
    cost: usize,
    field: Arc<Field>,
}

struct Inner {
    tick: u64,
    bytes: usize,
    map: HashMap<ChunkKey, Entry>,
}

/// Bounded byte-budget LRU over decoded chunks. Budget 0 disables caching
/// (every `get` misses, `insert` is a no-op) — the whole-container
/// decompression path uses that so batch decodes don't hoard memory.
pub struct ChunkCache {
    budget: usize,
    inner: Mutex<Inner>,
}

impl ChunkCache {
    /// Cache charging decoded chunks against a budget of `budget` bytes.
    pub fn new(budget: usize) -> Self {
        ChunkCache {
            budget,
            inner: Mutex::new(Inner { tick: 0, bytes: 0, map: HashMap::new() }),
        }
    }

    /// The byte budget (0 = caching disabled).
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Bytes currently charged (decoded payloads + per-entry overhead).
    pub fn bytes(&self) -> usize {
        self.inner.lock().map(|inner| inner.bytes).unwrap_or(0)
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.inner.lock().map(|inner| inner.map.len()).unwrap_or(0)
    }

    /// True if nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// What caching `field` under `key` would charge against the budget.
    pub fn entry_cost(key: &ChunkKey, field: &Field) -> usize {
        field.nbytes() + key.0.len() + ENTRY_OVERHEAD
    }

    /// Look up a decoded chunk, refreshing its recency on hit. Budget 0
    /// returns immediately — the batch decode path must not funnel every
    /// worker through the cache mutex for lookups that can never hit.
    pub fn get(&self, key: &ChunkKey) -> Option<Arc<Field>> {
        if self.budget == 0 {
            return None;
        }
        let Ok(mut inner) = self.inner.lock() else { return None };
        inner.tick += 1;
        let tick = inner.tick;
        let Some(e) = inner.map.get_mut(key) else {
            crate::obs::CACHE_MISSES.inc();
            return None;
        };
        e.stamp = tick;
        crate::obs::CACHE_HITS.inc();
        Some(Arc::clone(&e.field))
    }

    /// Insert a decoded chunk, evicting least-recently-used entries until
    /// the byte charge fits the budget. A chunk whose own cost exceeds the
    /// entire budget is not cached (and evicts any stale entry under the
    /// same key rather than leaving it to serve outdated bytes).
    pub fn insert(&self, key: ChunkKey, field: Arc<Field>) {
        if self.budget == 0 {
            return;
        }
        let cost = Self::entry_cost(&key, &field);
        let Ok(mut inner) = self.inner.lock() else { return };
        if let Some(old) = inner.map.remove(&key) {
            inner.bytes -= old.cost;
        }
        if cost > self.budget {
            crate::obs::CACHE_REJECTS.inc();
            crate::obs::CACHE_BYTES.set(inner.bytes as u64);
            crate::obs::CACHE_ENTRIES.set(inner.map.len() as u64);
            return;
        }
        inner.tick += 1;
        let stamp = inner.tick;
        inner.bytes += cost;
        inner.map.insert(key, Entry { stamp, cost, field });
        crate::obs::CACHE_INSERTS.inc();
        while inner.bytes > self.budget {
            let oldest = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone());
            match oldest.and_then(|k| inner.map.remove(&k)) {
                Some(evicted) => {
                    inner.bytes -= evicted.cost;
                    crate::obs::CACHE_EVICTIONS.inc();
                }
                // an empty map cannot out-charge the budget; stop, don't spin
                None => break,
            }
        }
        crate::obs::CACHE_BYTES.set(inner.bytes as u64);
        crate::obs::CACHE_ENTRIES.set(inner.map.len() as u64);
    }

    /// Drop every entry whose key belongs to cache scope `scope` (the
    /// prefix [`crate::reader::ContainerReader::with_shared_cache`]
    /// builds), returning how many entries were removed. The registry
    /// calls this when an artifact is deleted or replaced: each
    /// registration gets a fresh scope, so eviction here is byte
    /// reclamation — a retired artifact's chunks stop occupying budget —
    /// not a correctness requirement.
    pub fn evict_scope(&self, scope: &str) -> usize {
        if self.budget == 0 || scope.is_empty() {
            return 0;
        }
        // the same unit-separator framing with_shared_cache uses, so
        // scope "a" never matches keys of scope "ab"
        let prefix = format!("{scope}\u{1f}");
        let Ok(mut inner) = self.inner.lock() else { return 0 };
        let doomed: Vec<ChunkKey> = inner
            .map
            .keys()
            .filter(|(name, _)| name.starts_with(&prefix))
            .cloned()
            .collect();
        let mut removed = 0;
        for k in &doomed {
            if let Some(e) = inner.map.remove(k) {
                inner.bytes = inner.bytes.saturating_sub(e.cost);
                removed += 1;
                crate::obs::CACHE_EVICTIONS.inc();
            }
        }
        crate::obs::CACHE_BYTES.set(inner.bytes as u64);
        crate::obs::CACHE_ENTRIES.set(inner.map.len() as u64);
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A field charging exactly `4 * n` payload bytes.
    fn field(tag: usize, n: usize) -> Arc<Field> {
        Arc::new(Field::f32(format!("f{tag}"), &[n], vec![tag as f32; n]).unwrap())
    }

    fn key(i: usize) -> ChunkKey {
        ("f".to_string(), i)
    }

    /// Cost of one `field(_, n)` entry under `key(_)`.
    fn cost(n: usize) -> usize {
        ChunkCache::entry_cost(&key(0), &field(0, n))
    }

    #[test]
    fn hit_miss_and_byte_budget() {
        // room for exactly two 1024-element chunks, not three
        let c = ChunkCache::new(2 * cost(1024) + cost(1024) / 2);
        assert!(c.get(&key(0)).is_none());
        c.insert(key(0), field(0, 1024));
        c.insert(key(1), field(1, 1024));
        assert_eq!(c.len(), 2);
        assert_eq!(c.bytes(), 2 * cost(1024));
        assert!(c.get(&key(0)).is_some());
        // inserting a third evicts the LRU — key 1, since key 0 was touched
        c.insert(key(2), field(2, 1024));
        assert_eq!(c.len(), 2);
        assert!(c.get(&key(1)).is_none(), "LRU entry evicted");
        assert!(c.get(&key(0)).is_some());
        assert!(c.get(&key(2)).is_some());
        assert!(c.bytes() <= c.budget(), "charge never exceeds the budget");
    }

    #[test]
    fn get_refreshes_recency() {
        let c = ChunkCache::new(3 * cost(256));
        for i in 0..3 {
            c.insert(key(i), field(i, 256));
        }
        // touch 0 and 1; inserting 3 must evict 2
        c.get(&key(0));
        c.get(&key(1));
        c.insert(key(3), field(3, 256));
        assert!(c.get(&key(2)).is_none());
        assert!(c.get(&key(0)).is_some() && c.get(&key(1)).is_some());
    }

    #[test]
    fn zero_budget_disables_caching() {
        let c = ChunkCache::new(0);
        c.insert(key(0), field(0, 8));
        assert!(c.is_empty());
        assert_eq!(c.bytes(), 0);
        assert!(c.get(&key(0)).is_none());
    }

    #[test]
    fn oversized_entry_is_served_but_not_cached() {
        let c = ChunkCache::new(cost(64));
        // a small chunk fits ...
        c.insert(key(0), field(0, 64));
        assert_eq!(c.len(), 1);
        // ... a chunk bigger than the whole budget does not, and does not
        // wipe unrelated residents
        c.insert(key(1), field(1, 4096));
        assert!(c.get(&key(1)).is_none());
        assert!(c.get(&key(0)).is_some());
        // but it does retire a stale resident under its own key
        c.insert(key(0), field(9, 4096));
        assert!(c.get(&key(0)).is_none());
        assert_eq!(c.bytes(), 0);
    }

    #[test]
    fn reinsert_same_key_does_not_grow() {
        let c = ChunkCache::new(10 * cost(128));
        for _ in 0..10 {
            c.insert(key(7), field(7, 128));
        }
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), cost(128));
    }

    #[test]
    fn evict_scope_removes_exactly_one_scope() {
        let c = ChunkCache::new(100 * cost(64));
        // two scoped artifacts plus an unscoped private entry
        for i in 0..3 {
            c.insert((format!("a\u{1f}0\u{1e}rho"), i), field(i, 64));
            c.insert((format!("b\u{1f}0\u{1e}rho"), i), field(i, 64));
        }
        c.insert(key(0), field(9, 64));
        assert_eq!(c.len(), 7);
        let before = c.bytes();
        assert_eq!(c.evict_scope("a"), 3);
        assert_eq!(c.len(), 4);
        assert!(c.bytes() < before, "evicted bytes are uncharged");
        // scope "a" gone, scope "b" and the private entry untouched
        assert!(c.get(&(format!("a\u{1f}0\u{1e}rho"), 0)).is_none());
        assert!(c.get(&(format!("b\u{1f}0\u{1e}rho"), 0)).is_some());
        assert!(c.get(&key(0)).is_some());
        // prefix framing: scope "a" must not shadow scope "ab"
        c.insert((format!("ab\u{1f}0\u{1e}rho"), 0), field(1, 64));
        assert_eq!(c.evict_scope("a"), 0);
        assert!(c.get(&(format!("ab\u{1f}0\u{1e}rho"), 0)).is_some());
        // empty scope is a no-op, never a wildcard
        assert_eq!(c.evict_scope(""), 0);
        assert_eq!(c.evict_scope("missing"), 0);
    }

    #[test]
    fn eviction_frees_enough_for_mixed_sizes() {
        let c = ChunkCache::new(cost(100) + cost(200) + cost(400));
        c.insert(key(0), field(0, 100));
        c.insert(key(1), field(1, 200));
        c.insert(key(2), field(2, 400));
        assert_eq!(c.len(), 3);
        // one large insert evicts as many LRU entries as its size demands
        c.insert(key(3), field(3, 650));
        assert!(c.bytes() <= c.budget());
        assert!(c.get(&key(3)).is_some());
        assert!(c.get(&key(0)).is_none(), "oldest evicted first");
    }
}
