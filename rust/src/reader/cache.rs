//! LRU cache of decoded chunks keyed by `(field, chunk_index)` — the
//! serve-path accelerator: repeated region queries over the same hot
//! chunks skip fetch, CRC, and decode entirely.
//!
//! Implementation: a `HashMap` of entries stamped with a monotonically
//! increasing access tick; eviction scans for the minimum tick. O(n) per
//! eviction is deliberate — capacities are tens of chunks, and the scan is
//! trivially cheaper than a decode it stands in for.

use crate::data::Field;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Cache key: (field name, chunk index within the field).
pub type ChunkKey = (String, usize);

struct Inner {
    tick: u64,
    map: HashMap<ChunkKey, (u64, Arc<Field>)>,
}

/// Bounded LRU over decoded chunks. Capacity 0 disables caching (every
/// `get` misses, `insert` is a no-op) — the whole-container decompression
/// path uses that so batch decodes don't hoard memory.
pub struct ChunkCache {
    capacity: usize,
    inner: Mutex<Inner>,
}

impl ChunkCache {
    /// Cache holding at most `capacity` decoded chunks.
    pub fn new(capacity: usize) -> Self {
        ChunkCache {
            capacity,
            inner: Mutex::new(Inner { tick: 0, map: HashMap::new() }),
        }
    }

    /// Maximum entries.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// True if nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up a decoded chunk, refreshing its recency on hit. Capacity 0
    /// returns immediately — the batch decode path must not funnel every
    /// worker through the cache mutex for lookups that can never hit.
    pub fn get(&self, key: &ChunkKey) -> Option<Arc<Field>> {
        if self.capacity == 0 {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let (stamp, field) = inner.map.get_mut(key)?;
        *stamp = tick;
        Some(Arc::clone(field))
    }

    /// Insert a decoded chunk, evicting the least-recently-used entry when
    /// over capacity.
    pub fn insert(&self, key: ChunkKey, field: Arc<Field>) {
        if self.capacity == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(key, (tick, field));
        while inner.map.len() > self.capacity {
            let oldest = inner
                .map
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(k, _)| k.clone())
                .expect("non-empty map over capacity");
            inner.map.remove(&oldest);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn field(tag: usize) -> Arc<Field> {
        Arc::new(Field::f32(format!("f{tag}"), &[1], vec![tag as f32]).unwrap())
    }

    fn key(i: usize) -> ChunkKey {
        ("f".to_string(), i)
    }

    #[test]
    fn hit_miss_and_capacity() {
        let c = ChunkCache::new(2);
        assert!(c.get(&key(0)).is_none());
        c.insert(key(0), field(0));
        c.insert(key(1), field(1));
        assert_eq!(c.len(), 2);
        assert!(c.get(&key(0)).is_some());
        // inserting a third evicts the LRU — key 1, since key 0 was touched
        c.insert(key(2), field(2));
        assert_eq!(c.len(), 2);
        assert!(c.get(&key(1)).is_none(), "LRU entry evicted");
        assert!(c.get(&key(0)).is_some());
        assert!(c.get(&key(2)).is_some());
    }

    #[test]
    fn get_refreshes_recency() {
        let c = ChunkCache::new(3);
        for i in 0..3 {
            c.insert(key(i), field(i));
        }
        // touch 0 and 1; inserting 3 must evict 2
        c.get(&key(0));
        c.get(&key(1));
        c.insert(key(3), field(3));
        assert!(c.get(&key(2)).is_none());
        assert!(c.get(&key(0)).is_some() && c.get(&key(1)).is_some());
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let c = ChunkCache::new(0);
        c.insert(key(0), field(0));
        assert!(c.is_empty());
        assert!(c.get(&key(0)).is_none());
    }

    #[test]
    fn reinsert_same_key_does_not_grow() {
        let c = ChunkCache::new(2);
        for _ in 0..10 {
            c.insert(key(7), field(7));
        }
        assert_eq!(c.len(), 1);
    }
}
