//! Error types for the SZ3 framework.
//!
//! Hand-rolled `Display`/`Error` impls (thiserror is unavailable offline).

/// Unified error type for compression, decompression and runtime failures.
#[derive(Debug)]
pub enum SzError {
    /// The compressed stream is malformed or truncated.
    Corrupt(String),
    /// A pipeline was configured with incompatible modules or parameters.
    Config(String),
    /// Data shape does not match what the pipeline expects.
    Shape(String),
    /// Underlying lossless backend failed.
    Lossless(String),
    /// PJRT/XLA runtime failure (artifact load, compile, execute).
    Runtime(String),
    /// I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for SzError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SzError::Corrupt(m) => write!(f, "corrupt stream: {m}"),
            SzError::Config(m) => write!(f, "invalid configuration: {m}"),
            SzError::Shape(m) => write!(f, "shape mismatch: {m}"),
            SzError::Lossless(m) => write!(f, "lossless backend: {m}"),
            SzError::Runtime(m) => write!(f, "runtime: {m}"),
            SzError::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for SzError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SzError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SzError {
    fn from(e: std::io::Error) -> Self {
        SzError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, SzError>;

impl SzError {
    /// Helper for corrupt-stream errors.
    pub fn corrupt(msg: impl Into<String>) -> Self {
        SzError::Corrupt(msg.into())
    }
    /// Helper for configuration errors.
    pub fn config(msg: impl Into<String>) -> Self {
        SzError::Config(msg.into())
    }
    /// True for buffer-exhaustion errors (`ByteReader`'s "need N bytes,
    /// have M" shape, also used by the container index's entry-count
    /// bound): the parse failed because the *buffer* ended, not because
    /// the bytes were invalid. Incremental readers retry these with a
    /// longer prefix and fail fast on everything else.
    pub fn is_exhaustion(&self) -> bool {
        matches!(self, SzError::Corrupt(m) if m.starts_with("need ") && m.contains(" bytes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_and_message() {
        assert_eq!(SzError::corrupt("bad magic").to_string(), "corrupt stream: bad magic");
        assert_eq!(SzError::config("no").to_string(), "invalid configuration: no");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let e: SzError = std::io::Error::new(std::io::ErrorKind::Other, "disk").into();
        assert!(std::error::Error::source(&e).is_some());
    }
}
