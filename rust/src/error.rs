//! Error types for the SZ3 framework.

use thiserror::Error;

/// Unified error type for compression, decompression and runtime failures.
#[derive(Debug, Error)]
pub enum SzError {
    /// The compressed stream is malformed or truncated.
    #[error("corrupt stream: {0}")]
    Corrupt(String),
    /// A pipeline was configured with incompatible modules or parameters.
    #[error("invalid configuration: {0}")]
    Config(String),
    /// Data shape does not match what the pipeline expects.
    #[error("shape mismatch: {0}")]
    Shape(String),
    /// Underlying lossless backend failed.
    #[error("lossless backend: {0}")]
    Lossless(String),
    /// PJRT/XLA runtime failure (artifact load, compile, execute).
    #[error("runtime: {0}")]
    Runtime(String),
    /// I/O error.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, SzError>;

impl SzError {
    /// Helper for corrupt-stream errors.
    pub fn corrupt(msg: impl Into<String>) -> Self {
        SzError::Corrupt(msg.into())
    }
    /// Helper for configuration errors.
    pub fn config(msg: impl Into<String>) -> Self {
        SzError::Config(msg.into())
    }
}
