//! Tiny CLI argument parser substrate (clap is unavailable offline).
//!
//! Grammar: `sz3 <subcommand> [--flag value] [--switch] [positional...]`.
//! Flags may also be written `--flag=value`. A bare `--switch` is only
//! recognized when followed by another `--flag` or the end of the line —
//! `--switch positional` is ambiguous and parses as `--switch=positional`
//! (write `--switch` last, or use `=` forms, to avoid it).

use crate::error::{Result, SzError};
use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token.
    pub subcommand: String,
    /// `--key value` / `--key=value` pairs.
    pub flags: HashMap<String, String>,
    /// Bare `--switch` tokens.
    pub switches: Vec<String>,
    /// Remaining positional arguments.
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse an iterator of argument tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.switches.push(stripped.to_string());
                }
            } else if out.subcommand.is_empty() {
                out.subcommand = tok;
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    /// Required string flag.
    pub fn need(&self, key: &str) -> Result<&str> {
        self.flags
            .get(key)
            .map(String::as_str)
            .ok_or_else(|| SzError::config(format!("missing required --{key}")))
    }

    /// Optional string flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Optional typed flag with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                SzError::config(format!("--{key}: cannot parse '{v}'"))
            }),
        }
    }

    /// True if `--switch` was passed.
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Parse an optional comma-separated list flag
    /// (`--candidates sz3-lr,sz3-interp`). Empty items are dropped.
    pub fn list(&self, key: &str) -> Option<Vec<String>> {
        self.flags.get(key).map(|raw| {
            raw.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect()
        })
    }

    /// Parse a `--dims 100,500,500` style flag.
    pub fn dims(&self, key: &str) -> Result<Vec<usize>> {
        let raw = self.need(key)?;
        raw.split(',')
            .map(|p| {
                p.trim()
                    .parse::<usize>()
                    .map_err(|_| SzError::config(format!("bad dimension '{p}'")))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn full_grammar() {
        let a = parse(&[
            "compress", "--input", "x.f32", "--dims=4,5", "pos1", "--fast",
        ]);
        assert_eq!(a.subcommand, "compress");
        assert_eq!(a.need("input").unwrap(), "x.f32");
        assert_eq!(a.dims("dims").unwrap(), vec![4, 5]);
        assert!(a.has("fast"));
        assert_eq!(a.positionals, vec!["pos1"]);
        // ambiguity rule: a switch followed by a bare token consumes it
        let b = parse(&["x", "--fast", "pos1"]);
        assert_eq!(b.get("fast"), Some("pos1"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&["x", "--eb", "1e-3"]);
        assert_eq!(a.get_or("eb", 0.0f64).unwrap(), 1e-3);
        assert_eq!(a.get_or("radius", 32768u32).unwrap(), 32768);
        assert!(a.get_or::<f64>("eb2", 1.0).is_ok());
    }

    #[test]
    fn missing_required_flag_errors() {
        let a = parse(&["x"]);
        assert!(a.need("input").is_err());
        assert!(a.get_or::<u32>("eb", 1).is_ok());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["x", "--lo", "-5"]);
        assert_eq!(a.get_or("lo", 0i32).unwrap(), -5);
    }

    #[test]
    fn list_flag_splits_and_trims() {
        let a = parse(&["x", "--candidates", "sz3-lr, sz3-interp,,sz3-truncation"]);
        assert_eq!(
            a.list("candidates").unwrap(),
            vec!["sz3-lr", "sz3-interp", "sz3-truncation"]
        );
        assert!(a.list("missing").is_none());
    }
}
