//! Decorrelation substrate for the transform family: the ZFP-style
//! integer lifting transform over 4-element pencils, the sequency-order
//! coefficient permutation, and the negabinary mapping that feeds the
//! embedded bitplane coder.
//!
//! The lifting pair implements ZFP's non-orthogonal 4-point transform
//!
//! ```text
//!          ( 4  4  4  4 )                ( 4  6 -4 -1 )
//! F = 1/16 ( 5  1 -1 -5 )   F⁻¹ = 1/4   ( 4  2  4  5 )
//!          (-4  4  4 -4 )                ( 4 -2  4 -5 )
//!          (-2  6 -6  2 )                ( 4 -6 -4  1 )
//! ```
//!
//! as in-place integer shifts/adds, so `inverse(forward(x)) == x` exactly
//! for any fixed-point input with headroom. All arithmetic is wrapping:
//! the decode path runs on attacker-controlled coefficients, and a
//! hostile plane pattern must at worst reconstruct garbage values (caught
//! by the error-bound tests on honest streams), never panic.

use std::sync::OnceLock;

/// Negabinary conversion mask (1-bits at the odd positions).
const NB_MASK: u64 = 0xaaaa_aaaa_aaaa_aaaa;

/// Map a two's-complement integer to negabinary, where truncating low
/// bits perturbs the value by less than the weight of the lowest kept
/// bit — the property the embedded bitplane coder relies on.
#[inline]
pub fn to_negabinary(v: i64) -> u64 {
    ((v as u64).wrapping_add(NB_MASK)) ^ NB_MASK
}

/// Inverse of [`to_negabinary`].
#[inline]
pub fn from_negabinary(u: u64) -> i64 {
    ((u ^ NB_MASK).wrapping_sub(NB_MASK)) as i64
}

/// Forward lift of one 4-element pencil (in place).
#[inline]
fn fwd_lift4(p: &mut [i64; 4]) {
    let [mut x, mut y, mut z, mut w] = *p;
    x = x.wrapping_add(w);
    x >>= 1;
    w = w.wrapping_sub(x);
    z = z.wrapping_add(y);
    z >>= 1;
    y = y.wrapping_sub(z);
    x = x.wrapping_add(z);
    x >>= 1;
    z = z.wrapping_sub(x);
    w = w.wrapping_add(y);
    w >>= 1;
    y = y.wrapping_sub(w);
    w = w.wrapping_add(y >> 1);
    y = y.wrapping_sub(w >> 1);
    *p = [x, y, z, w];
}

/// Inverse lift of one 4-element pencil (in place).
#[inline]
fn inv_lift4(p: &mut [i64; 4]) {
    let [mut x, mut y, mut z, mut w] = *p;
    y = y.wrapping_add(w >> 1);
    w = w.wrapping_sub(y >> 1);
    y = y.wrapping_add(w);
    w = w.wrapping_shl(1);
    w = w.wrapping_sub(y);
    z = z.wrapping_add(x);
    x = x.wrapping_shl(1);
    x = x.wrapping_sub(z);
    y = y.wrapping_add(z);
    z = z.wrapping_shl(1);
    z = z.wrapping_sub(y);
    w = w.wrapping_add(x);
    x = x.wrapping_shl(1);
    x = x.wrapping_sub(w);
    *p = [x, y, z, w];
}

/// Lift every pencil along the axis with element `stride` (block layout
/// is row-major base-4, so a pencil base is any index whose axis digit
/// is zero).
fn lift_axis(block: &mut [i64], stride: usize, fwd: bool) {
    if stride == 0 {
        return;
    }
    let total = block.len();
    for base in 0..total {
        if (base / stride) % 4 != 0 {
            continue;
        }
        let mut p = [0i64; 4];
        for (j, slot) in p.iter_mut().enumerate() {
            *slot = block.get(base + j * stride).copied().unwrap_or(0);
        }
        if fwd {
            fwd_lift4(&mut p);
        } else {
            inv_lift4(&mut p);
        }
        for (j, &v) in p.iter().enumerate() {
            if let Some(slot) = block.get_mut(base + j * stride) {
                *slot = v;
            }
        }
    }
}

/// Forward block transform: lift each of the `d` (1..=3) axes, innermost
/// first. `block` is a row-major 4^d buffer.
pub fn forward(block: &mut [i64], d: usize) {
    let mut stride = 1usize;
    for _ in 0..d.clamp(1, 3) {
        lift_axis(block, stride, true);
        stride *= 4;
    }
}

/// Inverse block transform (exact inverse of [`forward`]): lift each
/// axis outermost first.
pub fn inverse(block: &mut [i64], d: usize) {
    let dd = d.clamp(1, 3);
    let mut stride = 1usize << (2 * (dd - 1));
    for _ in 0..dd {
        lift_axis(block, stride, false);
        stride /= 4;
    }
}

/// Coefficient visit order for a `d`-dimensional 4-side block: ascending
/// total sequency (sum of per-axis frequencies), ties broken by linear
/// index. Low-sequency (smooth) coefficients come first, so the embedded
/// bitplane coder's significance prefix grows front-to-back.
pub fn sequency_order(d: usize) -> &'static [usize] {
    static ORDERS: OnceLock<[Vec<usize>; 3]> = OnceLock::new();
    let all = ORDERS.get_or_init(|| {
        let build = |dd: usize| {
            let n = 1usize << (2 * dd);
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by_key(|&i| {
                let fx = i & 3;
                let fy = (i >> 2) & 3;
                let fz = (i >> 4) & 3;
                (fx + fy + fz, i)
            });
            order
        };
        [build(1), build(2), build(3)]
    });
    all.get(d.clamp(1, 3) - 1).map(|v| v.as_slice()).unwrap_or(&[])
}
