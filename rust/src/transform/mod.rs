//! Transform-domain compression family (ZFP-style) — the repo's first
//! non-prediction algorithm class, giving the adaptive selector a rival
//! with a genuinely different rate-distortion profile.
//!
//! The field is tiled into fixed 4ᵈ blocks (d = dimensionality, capped
//! at 3 by merging leading axes). Each block is aligned to a block-local
//! fixed point (scaled by 2^(55−eₘₐₓ) so the widest value uses 55 bits
//! of an i64, leaving headroom for the transform), decorrelated with the
//! integer lifting transform ([`lift`]), reordered by total sequency,
//! mapped to negabinary, and coded as group-tested bitplanes, most
//! significant first ([`bitplane`]). The encoder keeps only as many
//! planes as the reconstruction bound needs — decided per block by
//! reconstructing and verifying against the original values, so the
//! error bound is honored by construction; blocks that cannot meet the
//! bound at full precision (e.g. f64 data with a bound below the fixed
//! point's resolution) fall back to a verbatim patch, and constant
//! blocks store a single value.
//!
//! Spec grammar: `tblock(4)/bitplane[@pN]/raw/<lossless>` (registry
//! alias `zfp-like`); `@pN` pins a minimum of N kept planes as a
//! fidelity floor on top of the bound-derived cutoff.
//!
//! Stream layout after the common [`StreamHeader`]:
//!
//! ```text
//! u8 pinned_planes · str lossless ·
//! block( lossless( block(meta) · block(planes) ) )
//! ```
//!
//! `meta` holds one record per block in grid row-major order — `u8 mode`
//! then, by mode: constant → `f64 value`; coded → `u16 biased scale
//! exponent · u8 kept planes`; verbatim → 4ᵈ `f64` values. `planes` is
//! the shared embedded bitstream of every coded block in order. The
//! decode path is panic-free under arbitrary corruption: every section
//! length is cross-checked before allocation and every read is bounded
//! (this module is in the audit trust map).

pub mod bitplane;
pub mod lift;

#[cfg(test)]
mod tests;

use crate::bitio::{BitReader, BitWriter};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::{Field, FieldValues};
use crate::error::{Result, SzError};
use crate::lossless;
use crate::pipeline::{CompressConf, Compressor, StreamHeader};

/// Fixed block side (ZFP-style).
pub const BLOCK_SIDE: usize = 4;

/// Fixed-point scale target: the widest block value maps to ≤ 2^55,
/// leaving 8 bits of i64 headroom for the lifting transform's gain.
const SCALE_BITS: i32 = 55;

/// Scale-exponent clamp keeping `2^±se` a finite f64.
const SE_LIMIT: i32 = 1021;

const MODE_CONST: u8 = 0;
const MODE_CODED: u8 = 1;
const MODE_VERBATIM: u8 = 2;

/// The transform-family compressor
/// (`tblock(4)/bitplane[@pN]/raw/<lossless>`, alias `zfp-like`).
pub struct TransformCompressor {
    /// Pipeline identity written to stream headers (canonical spec or
    /// registry alias).
    pub name: String,
    /// Minimum kept planes per coded block (`@pN`), a fidelity floor on
    /// top of the bound-derived cutoff. `None` = bound-derived only.
    pub planes: Option<u32>,
    /// Lossless backend token (may carry a level, e.g. `zstd@l19`).
    pub lossless: String,
}

impl Default for TransformCompressor {
    fn default() -> Self {
        TransformCompressor {
            name: "zfp-like".to_string(),
            planes: None,
            lossless: "zstd".to_string(),
        }
    }
}

/// Block grid geometry over the effective ≤3-axis shape. Fields with
/// more than 3 axes merge their leading axes into one.
struct Grid {
    /// Effective extents, slowest first (length padded to 3 with 1s).
    e: [usize; 3],
    /// Block shape per axis (4 on transformed axes, 1 on padded ones).
    s: [usize; 3],
    /// Block counts per axis.
    c: [usize; 3],
    /// Transform dimensionality (1..=3).
    d: usize,
    /// Cells per block (4^d).
    nvals: usize,
    /// Total blocks.
    nblocks: usize,
}

impl Grid {
    /// Build the grid for a field shape. `dims` must be non-empty with
    /// no zero axes (both guaranteed by [`StreamHeader::read`] and
    /// [`Field::new`]); the element count is capped by the header cap,
    /// so products cannot overflow.
    fn from_dims(dims: &[usize]) -> Result<Grid> {
        if dims.is_empty() || dims.iter().any(|&x| x == 0) {
            return Err(SzError::corrupt("transform stream has a degenerate shape"));
        }
        let nd = dims.len();
        let d = nd.clamp(1, 3);
        let e = match nd {
            1 => [1, 1, dims.first().copied().unwrap_or(1)],
            2 => [
                1,
                dims.first().copied().unwrap_or(1),
                dims.get(1).copied().unwrap_or(1),
            ],
            _ => {
                let lead: usize =
                    dims.get(..nd - 2).map(|s| s.iter().product()).unwrap_or(1);
                [
                    lead,
                    dims.get(nd - 2).copied().unwrap_or(1),
                    dims.get(nd - 1).copied().unwrap_or(1),
                ]
            }
        };
        // the last `d` axes carry the transform
        let mut s = [1usize; 3];
        for (a, slot) in s.iter_mut().enumerate() {
            if a >= 3 - d {
                *slot = BLOCK_SIDE;
            }
        }
        let mut c = [1usize; 3];
        for ((slot, &ext), &side) in c.iter_mut().zip(e.iter()).zip(s.iter()) {
            *slot = ext.div_ceil(side);
        }
        let [c0, c1, c2] = c;
        let nblocks = c0
            .checked_mul(c1)
            .and_then(|x| x.checked_mul(c2))
            .ok_or_else(|| SzError::corrupt("transform block count overflows"))?;
        let [s0, s1, s2] = s;
        Ok(Grid { e, s, c, d, nvals: s0 * s1 * s2, nblocks })
    }

    /// Visit every cell of block `b` in row-major order. The callback
    /// gets `(cell index, clamped linear field index, in bounds)` —
    /// out-of-bounds cells (edge padding) clamp to the nearest edge
    /// value on gather and are skipped on scatter.
    fn visit(&self, b: usize, mut f: impl FnMut(usize, usize, bool)) {
        let [e0, e1, e2] = self.e;
        let [s0, s1, s2] = self.s;
        let [_, c1, c2] = self.c;
        let b2 = b % c2;
        let t = b / c2;
        let b1 = t % c1;
        let b0 = t / c1;
        let (o0, o1, o2) = (b0 * s0, b1 * s1, b2 * s2);
        let mut k = 0usize;
        for l0 in 0..s0 {
            let h0 = o0 + l0;
            let g0 = h0.min(e0 - 1);
            for l1 in 0..s1 {
                let h1 = o1 + l1;
                let g1 = h1.min(e1 - 1);
                for l2 in 0..s2 {
                    let h2 = o2 + l2;
                    let g2 = h2.min(e2 - 1);
                    let lin = (g0 * e1 + g1) * e2 + g2;
                    f(k, lin, h0 < e0 && h1 < e1 && h2 < e2);
                    k += 1;
                }
            }
        }
    }
}

/// frexp-convention binary exponent: `2^(e-1) <= |v| < 2^e` for normal
/// `v` (subnormals report the minimum normal exponent; the scale clamp
/// and the reconstruct-and-verify cutoff absorb the difference).
fn exponent(v: f64) -> i32 {
    (((v.to_bits() >> 52) & 0x7ff) as i32) - 1022
}

/// Storage-dtype cast roundtrip: the error the *decompressed* value
/// shows is measured after casting back to the field's dtype, so the
/// encoder's cutoff search must verify through the same cast.
fn cast_roundtrip(dtype: &str) -> fn(f64) -> f64 {
    match dtype {
        "f32" => |v| v as f32 as f64,
        "i32" => |v| (v.round() as i32) as f64,
        _ => |v| v,
    }
}

/// Encode one gathered block into `meta`/`planes`.
fn encode_block(
    cell: &[f64],
    grid: &Grid,
    eb: f64,
    pinned: u32,
    cast: fn(f64) -> f64,
    meta: &mut ByteWriter,
    planes: &mut BitWriter,
) {
    let first = cell.first().copied().unwrap_or(0.0);
    if cell.iter().all(|v| v.to_bits() == first.to_bits()) {
        meta.put_u8(MODE_CONST);
        meta.put_f64(first);
        return;
    }
    let verbatim = |meta: &mut ByteWriter| {
        meta.put_u8(MODE_VERBATIM);
        for &v in cell {
            meta.put_f64(v);
        }
    };
    if cell.iter().any(|v| !v.is_finite()) {
        verbatim(meta);
        return;
    }
    // block-local fixed point: widest value uses SCALE_BITS bits
    let emax = cell
        .iter()
        .filter(|v| **v != 0.0)
        .map(|&v| exponent(v))
        .max()
        .unwrap_or(0);
    let se = (SCALE_BITS - emax).clamp(-SE_LIMIT, SE_LIMIT);
    let scale = 2f64.powi(se);
    let mut ints: Vec<i64> = cell.iter().map(|&v| (v * scale).round() as i64).collect();
    lift::forward(&mut ints, grid.d);
    let perm = lift::sequency_order(grid.d);
    let useq: Vec<u64> = perm
        .iter()
        .map(|&src| lift::to_negabinary(ints.get(src).copied().unwrap_or(0)))
        .collect();
    // reconstruct-and-verify: max pointwise error (through the dtype
    // cast) when only the top `kept` planes survive — exactly what the
    // decoder will compute
    let descale = 2f64.powi(-se);
    let err_at = |kept: u32| -> f64 {
        let mask = if kept >= 64 { u64::MAX } else { u64::MAX << (64 - kept) };
        let mut rec = vec![0i64; grid.nvals];
        for (&src, &u) in perm.iter().zip(useq.iter()) {
            if let Some(slot) = rec.get_mut(src) {
                *slot = lift::from_negabinary(u & mask);
            }
        }
        lift::inverse(&mut rec, grid.d);
        let mut worst = 0f64;
        for (&c, &orig) in rec.iter().zip(cell.iter()) {
            let v = cast(c as f64 * descale);
            worst = worst.max((v - orig).abs());
        }
        worst
    };
    // analytic first guess (int-domain tolerance eb·scale, plus slack
    // for the transform gain), then walk to the exact cutoff
    let tol = eb * scale;
    let guess = if tol.is_finite() && tol > 1.0 {
        (68.0 - tol.log2().floor()).clamp(1.0, 64.0) as u32
    } else {
        64
    };
    let mut kept = guess;
    let mut worst = err_at(kept);
    while worst > eb && kept < 64 {
        kept += 1;
        worst = err_at(kept);
    }
    if worst > eb {
        // bound unreachable at full fixed-point precision: patch the
        // block verbatim (exact for every supported dtype)
        verbatim(meta);
        return;
    }
    while kept > 1 && err_at(kept - 1) <= eb {
        kept -= 1;
    }
    let kept = kept.max(pinned).max(1);
    meta.put_u8(MODE_CODED);
    meta.put_u16((se + SE_LIMIT) as u16);
    meta.put_u8(kept as u8);
    bitplane::encode(&useq, kept, planes);
}

impl TransformCompressor {
    fn compress_impl(&self, field: &Field, conf: &CompressConf) -> Result<Vec<u8>> {
        let eb = conf.bound.to_abs(field)?;
        let grid = Grid::from_dims(field.shape.dims())?;
        let data = field.values.to_f64_vec();
        let cast = cast_roundtrip(field.values.dtype());
        let pinned = self.planes.unwrap_or(0).min(64);
        let mut meta = ByteWriter::new();
        let mut planes = BitWriter::new();
        let mut cell = vec![0f64; grid.nvals];
        for b in 0..grid.nblocks {
            grid.visit(b, |k, lin, _| {
                if let Some(slot) = cell.get_mut(k) {
                    *slot = data.get(lin).copied().unwrap_or(0.0);
                }
            });
            encode_block(&cell, &grid, eb, pinned, cast, &mut meta, &mut planes);
        }
        let ll = lossless::by_name(&self.lossless).ok_or_else(|| {
            SzError::config(format!("unknown lossless backend '{}'", self.lossless))
        })?;
        let mut body = ByteWriter::new();
        body.put_block(&meta.finish());
        body.put_block(&planes.finish());
        let mut w = ByteWriter::new();
        StreamHeader::for_field(&self.name, field).write(&mut w);
        w.put_u8(pinned as u8);
        w.put_str(&self.lossless);
        w.put_block(&ll.compress(&body.finish())?);
        Ok(w.finish())
    }

    fn decompress_impl(&self, stream: &[u8]) -> Result<Field> {
        let mut r = ByteReader::new(stream);
        let header = StreamHeader::read(&mut r)?;
        let pinned = r.get_u8()?;
        if pinned > 64 {
            return Err(SzError::corrupt("pinned plane count out of range"));
        }
        let ll_name = r.get_str()?;
        let ll = lossless::by_name(&ll_name).ok_or_else(|| {
            SzError::corrupt(format!("stream names unknown lossless '{ll_name}'"))
        })?;
        let body = ll.decompress(r.get_block()?)?;
        if r.remaining() != 0 {
            return Err(SzError::corrupt("trailing bytes after transform payload"));
        }
        let mut br = ByteReader::new(&body);
        let meta = br.get_block()?;
        let planes = br.get_block()?;
        if br.remaining() != 0 {
            return Err(SzError::corrupt("trailing bytes in transform body"));
        }
        let grid = Grid::from_dims(&header.dims)?;
        // every block owns ≥ 1 meta byte: cross-check before sizing the
        // output allocation from the header
        if meta.len() < grid.nblocks {
            return Err(SzError::corrupt("meta section shorter than block count"));
        }
        let perm = lift::sequency_order(grid.d);
        let mut out = vec![0f64; header.len()];
        let mut mr = ByteReader::new(meta);
        let mut pr = BitReader::new(planes);
        let mut cell = vec![0f64; grid.nvals];
        for b in 0..grid.nblocks {
            match mr.get_u8()? {
                MODE_CONST => {
                    let v = mr.get_f64()?;
                    cell.fill(v);
                }
                MODE_CODED => {
                    let seb = mr.get_u16()?;
                    let se = (seb as i32) - SE_LIMIT;
                    if !(-SE_LIMIT..=SE_LIMIT).contains(&se) {
                        return Err(SzError::corrupt("scale exponent out of range"));
                    }
                    let kept = mr.get_u8()?;
                    if kept == 0 || kept > 64 {
                        return Err(SzError::corrupt("kept plane count out of range"));
                    }
                    let useq = bitplane::decode(grid.nvals, kept as u32, &mut pr)?;
                    let mut ints = vec![0i64; grid.nvals];
                    for (&src, &u) in perm.iter().zip(useq.iter()) {
                        if let Some(slot) = ints.get_mut(src) {
                            *slot = lift::from_negabinary(u);
                        }
                    }
                    lift::inverse(&mut ints, grid.d);
                    let descale = 2f64.powi(-se);
                    for (slot, &c) in cell.iter_mut().zip(ints.iter()) {
                        *slot = c as f64 * descale;
                    }
                }
                MODE_VERBATIM => {
                    for slot in cell.iter_mut() {
                        *slot = mr.get_f64()?;
                    }
                }
                other => {
                    return Err(SzError::corrupt(format!(
                        "unknown transform block mode {other}"
                    )));
                }
            }
            grid.visit(b, |k, lin, valid| {
                if valid {
                    let v = cell.get(k).copied().unwrap_or(0.0);
                    if let Some(slot) = out.get_mut(lin) {
                        *slot = v;
                    }
                }
            });
        }
        if mr.remaining() != 0 {
            return Err(SzError::corrupt("trailing meta bytes"));
        }
        if pr.bit_len().saturating_sub(pr.bit_pos()) >= 8 {
            return Err(SzError::corrupt("trailing plane bytes"));
        }
        let fv = match header.dtype.as_str() {
            "f32" => FieldValues::F32(out.iter().map(|&v| v as f32).collect()),
            "f64" => FieldValues::F64(out),
            "i32" => {
                FieldValues::I32(out.iter().map(|&v| v.round() as i32).collect())
            }
            other => {
                return Err(SzError::corrupt(format!(
                    "unsupported dtype '{other}' in transform stream"
                )));
            }
        };
        Field::new(header.field_name.clone(), &header.dims, fv)
    }
}

impl Compressor for TransformCompressor {
    fn name(&self) -> &str {
        &self.name
    }

    fn compress(&self, field: &Field, conf: &CompressConf) -> Result<Vec<u8>> {
        self.compress_impl(field, conf)
    }

    fn decompress(&self, stream: &[u8]) -> Result<Field> {
        self.decompress_impl(stream)
    }
}
