use super::*;
use crate::pipeline::{decompress_any, test_support::roundtrip_bound_check, ErrorBound};
use crate::util::prop;
use crate::util::rng::Pcg32;

fn tc() -> TransformCompressor {
    TransformCompressor::default()
}

// ---- substrate units ------------------------------------------------------

#[test]
fn negabinary_roundtrips_and_truncation_is_small() {
    let mut rng = Pcg32::seeded(0x4e6);
    for _ in 0..2000 {
        let v = (rng.next_u64() as i64) >> (rng.below(20) as u32);
        assert_eq!(lift::from_negabinary(lift::to_negabinary(v)), v);
        // zeroing the low m bits of the negabinary word moves the value
        // by less than 2^m — the property plane truncation relies on
        let m = rng.below(40) as u32 + 1;
        let mask = u64::MAX << m;
        let trunc = lift::from_negabinary(lift::to_negabinary(v) & mask);
        assert!(
            (v.wrapping_sub(trunc)).unsigned_abs() < 1u64 << m,
            "v {v} trunc {trunc} m {m}"
        );
    }
}

#[test]
fn lift_inverse_is_exact() {
    let mut rng = Pcg32::seeded(0x11f7);
    for d in 1..=3usize {
        let n = 1usize << (2 * d);
        for _ in 0..500 {
            // 55-bit fixed-point magnitudes, the encoder's headroom contract
            let orig: Vec<i64> =
                (0..n).map(|_| (rng.next_u64() as i64) >> 9).collect();
            let mut work = orig.clone();
            lift::forward(&mut work, d);
            lift::inverse(&mut work, d);
            assert_eq!(work, orig, "d={d}");
        }
    }
}

#[test]
fn sequency_order_is_a_permutation_sorted_by_total_frequency() {
    for d in 1..=3usize {
        let n = 1usize << (2 * d);
        let perm = lift::sequency_order(d);
        assert_eq!(perm.len(), n);
        let mut seen = vec![false; n];
        for &i in perm {
            assert!(!seen[i], "duplicate {i}");
            seen[i] = true;
        }
        let seq = |i: usize| (i & 3) + ((i >> 2) & 3) + ((i >> 4) & 3);
        for pair in perm.windows(2) {
            assert!(seq(pair[0]) <= seq(pair[1]), "not sorted: {pair:?}");
        }
    }
}

#[test]
fn bitplane_decode_returns_exactly_the_kept_planes() {
    let mut rng = Pcg32::seeded(0xb17e);
    for _ in 0..300 {
        let n = rng.below(64) + 1;
        let coeffs: Vec<u64> = (0..n)
            .map(|_| {
                // skewed magnitudes like real transform output
                rng.next_u64() >> (rng.below(60) as u32)
            })
            .collect();
        let kept = rng.below(64) as u32 + 1;
        let mask = if kept >= 64 { u64::MAX } else { u64::MAX << (64 - kept) };
        let mut w = crate::bitio::BitWriter::new();
        bitplane::encode(&coeffs, kept, &mut w);
        let bytes = w.finish();
        let mut r = crate::bitio::BitReader::new(&bytes);
        let dec = bitplane::decode(n, kept, &mut r).unwrap();
        let want: Vec<u64> = coeffs.iter().map(|&c| c & mask).collect();
        assert_eq!(dec, want, "n={n} kept={kept}");
    }
}

#[test]
fn bitplane_rejects_bad_group_sizes_and_truncated_streams() {
    let mut r = crate::bitio::BitReader::new(&[]);
    assert!(bitplane::decode(0, 8, &mut r).is_err());
    let mut r = crate::bitio::BitReader::new(&[]);
    assert!(bitplane::decode(65, 8, &mut r).is_err());
    // a stream that demands more bits than available must error
    let mut w = crate::bitio::BitWriter::new();
    bitplane::encode(&[u64::MAX; 64], 64, &mut w);
    let bytes = w.finish();
    let mut r = crate::bitio::BitReader::new(&bytes[..bytes.len() / 2]);
    assert!(bitplane::decode(64, 64, &mut r).is_err());
}

// ---- end-to-end family ----------------------------------------------------

#[test]
fn prop_roundtrip_bound_on_smooth_fields() {
    prop::cases(30, 0x7f0, |rng| {
        let dims: Vec<usize> = match rng.below(3) {
            0 => vec![rng.below(200) + 1],
            1 => vec![rng.below(24) + 1, rng.below(24) + 1],
            _ => vec![rng.below(10) + 1, rng.below(10) + 1, rng.below(10) + 1],
        };
        let vals = prop::smooth_field(rng, &dims);
        let f = Field::f32("s", &dims, vals).unwrap();
        let eb = 10f64.powf(rng.uniform(-5.0, -1.0));
        roundtrip_bound_check(&tc(), &f, &CompressConf::new(ErrorBound::Abs(eb)));
    });
}

#[test]
fn prop_roundtrip_bound_on_noise_and_rel_bounds() {
    prop::cases(25, 0x7f1, |rng| {
        let n = rng.below(2000) + 1;
        let vals = prop::vec_f32(rng, n);
        let f = Field::f32("w", &[n], vals).unwrap();
        let conf = if rng.below(2) == 0 {
            CompressConf::new(ErrorBound::Abs(10f64.powf(rng.uniform(-4.0, 0.0))))
        } else {
            CompressConf::new(ErrorBound::Rel(10f64.powf(rng.uniform(-5.0, -2.0))))
        };
        roundtrip_bound_check(&tc(), &f, &conf);
    });
}

#[test]
fn all_dtypes_roundtrip() {
    let conf = CompressConf::new(ErrorBound::Abs(0.5));
    let f32s = Field::f32("a", &[10, 10], (0..100).map(|i| i as f32 * 0.3).collect()).unwrap();
    let f64s = Field::f64("b", &[100], (0..100).map(|i| (i as f64).sin()).collect()).unwrap();
    let i32s =
        Field::new("c", &[100], FieldValues::I32((0..100).map(|i| i * 7 - 350).collect()))
            .unwrap();
    for f in [&f32s, &f64s, &i32s] {
        roundtrip_bound_check(&tc(), f, &conf);
    }
}

#[test]
fn awkward_shapes_roundtrip() {
    // partial edge blocks on every axis, plus >3-d axis merging
    let shapes: &[&[usize]] = &[
        &[1],
        &[5],
        &[4, 4],
        &[5, 7],
        &[1, 9],
        &[3, 3, 3],
        &[4, 5, 6],
        &[2, 3, 4, 5],
        &[2, 2, 2, 2, 3],
    ];
    for dims in shapes {
        let n: usize = dims.iter().product();
        let vals: Vec<f32> = (0..n).map(|i| ((i * 37 % 97) as f32).sqrt()).collect();
        let f = Field::f32("shape", dims, vals).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(1e-3));
        roundtrip_bound_check(&tc(), &f, &conf);
    }
}

#[test]
fn constant_field_compresses_hard() {
    let f = Field::f32("flat", &[64, 64], vec![13.25; 4096]).unwrap();
    let conf = CompressConf::new(ErrorBound::Abs(1e-6));
    let ratio = roundtrip_bound_check(&tc(), &f, &conf);
    assert!(ratio > 20.0, "constant field ratio {ratio}");
}

#[test]
fn smooth_field_beats_raw_storage() {
    let mut rng = Pcg32::seeded(0x57e9);
    let vals = prop::smooth_field(&mut rng, &[32, 32]);
    let f = Field::f32("smooth", &[32, 32], vals).unwrap();
    let conf = CompressConf::new(ErrorBound::Abs(1e-2));
    let ratio = roundtrip_bound_check(&tc(), &f, &conf);
    assert!(ratio > 1.5, "smooth field ratio {ratio}");
}

#[test]
fn nan_survives_the_verbatim_path() {
    let mut vals = vec![1.5f32; 80];
    vals[40] = f32::NAN;
    vals[41] = f32::INFINITY;
    let f = Field::f32("nan", &[80], vals).unwrap();
    let conf = CompressConf::new(ErrorBound::Abs(1e-3));
    let stream = tc().compress(&f, &conf).unwrap();
    let out = decompress_any(&stream).unwrap();
    let FieldValues::F32(dec) = &out.values else { panic!("dtype") };
    assert!(dec[40].is_nan());
    assert_eq!(dec[41], f32::INFINITY);
    assert_eq!(dec[0], 1.5);
    assert_eq!(dec[79], 1.5);
}

#[test]
fn unreachable_bound_falls_back_to_exact_verbatim() {
    // f64 data under a bound far below the fixed point's resolution:
    // every non-constant block must patch verbatim and round-trip exactly
    let mut rng = Pcg32::seeded(0xfa11);
    let vals: Vec<f64> = (0..200).map(|_| rng.uniform(-1e9, 1e9)).collect();
    let f = Field::f64("exact", &[200], vals.clone()).unwrap();
    let conf = CompressConf::new(ErrorBound::Abs(1e-300));
    let out = decompress_any(&tc().compress(&f, &conf).unwrap()).unwrap();
    assert_eq!(out.values, FieldValues::F64(vals));
}

#[test]
fn pinned_planes_raise_fidelity_and_bytes() {
    let mut rng = Pcg32::seeded(0x91e);
    let vals: Vec<f64> =
        prop::smooth_field(&mut rng, &[24, 24]).iter().map(|&v| v as f64).collect();
    let f = Field::f64("pin", &[24, 24], vals.clone()).unwrap();
    let conf = CompressConf::new(ErrorBound::Abs(0.25));
    let loose = tc().compress(&f, &conf).unwrap();
    let pinned =
        TransformCompressor { planes: Some(56), ..Default::default() }.compress(&f, &conf).unwrap();
    assert!(pinned.len() > loose.len(), "{} !> {}", pinned.len(), loose.len());
    let max_err = |stream: &[u8]| -> f64 {
        let out = decompress_any(stream).unwrap();
        let FieldValues::F64(dec) = &out.values else { panic!("dtype") };
        dec.iter().zip(vals.iter()).map(|(d, o)| (d - o).abs()).fold(0.0, f64::max)
    };
    let e_loose = max_err(&loose);
    let e_pinned = max_err(&pinned);
    assert!(e_loose <= 0.25);
    // 56 of 64 planes is far tighter than the 0.25 bound requires
    assert!(e_pinned < e_loose / 100.0, "pinned {e_pinned} loose {e_loose}");
}

#[test]
fn stored_lossless_token_drives_decode() {
    // decode must honor the lossless named in the stream, not the
    // decompressor instance's own config
    let f = Field::f32("ll", &[40], (0..40).map(|i| i as f32).collect()).unwrap();
    let conf = CompressConf::new(ErrorBound::Abs(1e-4));
    let c = TransformCompressor { lossless: "gzip".to_string(), ..Default::default() };
    let stream = c.compress(&f, &conf).unwrap();
    roundtrip_bound_check(&c, &f, &conf);
    // a default (zstd-configured) instance still decodes the gzip stream
    let out = tc().decompress(&stream).unwrap();
    assert_eq!(out.shape.dims(), &[40]);
}

#[test]
fn unknown_lossless_rejected_at_compress_time() {
    let f = Field::f32("x", &[8], vec![0.5; 8]).unwrap();
    let conf = CompressConf::new(ErrorBound::Abs(0.1));
    let c = TransformCompressor { lossless: "nope".to_string(), ..Default::default() };
    assert!(c.compress(&f, &conf).is_err());
}

#[test]
fn corrupt_sections_error_not_panic() {
    let mut rng = Pcg32::seeded(0xc0de);
    let vals = prop::smooth_field(&mut rng, &[17, 13]);
    let f = Field::f32("x", &[17, 13], vals).unwrap();
    let conf = CompressConf::new(ErrorBound::Abs(1e-4));
    let c = tc();
    let stream = c.compress(&f, &conf).unwrap();
    // truncating the stream at every prefix must error cleanly
    for cut in 0..stream.len() {
        assert!(c.decompress(&stream[..cut]).is_err(), "prefix {cut} accepted");
    }
    // flipping bytes across the stream must never panic (it may decode
    // to junk values, but structural checks catch length lies)
    for at in 0..stream.len() {
        let mut bad = stream.clone();
        bad[at] ^= 0xA5;
        let _ = std::panic::catch_unwind(|| c.decompress(&bad))
            .expect("decompress must not panic");
    }
}
