//! Embedded bitplane coder: group-tested negabinary planes, most
//! significant first (the ZFP embedded coding scheme).
//!
//! Coefficients arrive in sequency order as 64-bit negabinary words.
//! Planes are emitted from bit 63 downward; within a plane the first
//! `sig` coefficients (the prefix already known significant from higher
//! planes) get verbatim bits, and the remainder is run-length coded with
//! group tests: one bit answers "is anything in the tail significant in
//! this plane?", then unary position bits walk to each newly significant
//! coefficient. Truncating the stream after any plane leaves every
//! coefficient with its top planes intact — the embedded property the
//! encoder's reconstruct-and-verify cutoff search relies on.

use crate::bitio::{BitReader, BitWriter};
use crate::error::{Result, SzError};

/// Encode the top `kept` (1..=64) bitplanes of `coeffs` (≤ 64 negabinary
/// words in sequency order) into `w`.
pub fn encode(coeffs: &[u64], kept: u32, w: &mut BitWriter) {
    let nvals = coeffs.len();
    let mut sig = 0usize;
    let lo = 64u32.saturating_sub(kept.min(64));
    let mut plane = 64u32;
    while plane > lo {
        plane -= 1;
        // plane word: bit i of x = bit `plane` of coeffs[i]
        let mut x = 0u64;
        for (i, &c) in coeffs.iter().enumerate() {
            x |= ((c >> plane) & 1) << i;
        }
        // verbatim bits for the known-significant prefix
        for i in 0..sig {
            w.put_bit(((x >> i) & 1) as u32);
        }
        x = if sig >= 64 { 0 } else { x >> sig };
        // group-tested unary coding of the tail
        let mut p = sig;
        while p < nvals {
            let any = (x != 0) as u32;
            w.put_bit(any);
            if any == 0 {
                break;
            }
            while p + 1 < nvals {
                let bit = (x & 1) as u32;
                w.put_bit(bit);
                if bit == 1 {
                    break;
                }
                x >>= 1;
                p += 1;
            }
            x >>= 1;
            p += 1;
        }
        sig = p;
    }
}

/// Decode `nvals` (1..=64) coefficients from the top `kept` bitplanes in
/// `r` — the exact inverse of [`encode`]. Bits below plane `64 - kept`
/// are zero in the result. Errors (never panics) on a truncated stream.
pub fn decode(nvals: usize, kept: u32, r: &mut BitReader) -> Result<Vec<u64>> {
    if nvals == 0 || nvals > 64 {
        return Err(SzError::corrupt("bitplane group size out of range"));
    }
    let mut coeffs = vec![0u64; nvals];
    let mut sig = 0usize;
    let lo = 64u32.saturating_sub(kept.min(64));
    let mut plane = 64u32;
    while plane > lo {
        plane -= 1;
        let mut x = 0u64;
        for i in 0..sig {
            x |= (r.get_bit()? as u64) << i;
        }
        let mut p = sig;
        while p < nvals {
            if r.get_bit()? == 0 {
                break;
            }
            while p + 1 < nvals {
                if r.get_bit()? == 1 {
                    break;
                }
                p += 1;
            }
            x |= 1u64 << p;
            p += 1;
        }
        sig = p;
        for (i, slot) in coeffs.iter_mut().enumerate() {
            *slot |= ((x >> i) & 1) << plane;
        }
    }
    Ok(coeffs)
}
